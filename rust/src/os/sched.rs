//! N concurrent elasticized processes per cluster.
//!
//! [`ElasticCluster`] owns one [`NodeKernel`] plus a real process
//! table, and a round-robin scheduler that time-slices N workloads on
//! the shared [`SimClock`]: each runnable process executes until its
//! quantum of simulated time expires, so processes stretch, fault, and
//! jump *independently* while competing for the same frames — the
//! contention workload FluidMem (arXiv:1707.07780) and the
//! disaggregation surveys identify as the defining datacenter case,
//! and exactly what the paper's EOS manager (Fig 3) is specified to
//! monitor: a table of processes, not one.
//!
//! A tenant is either **live** or a **trace** ([`TenantJob`]):
//!
//! * A live tenant is a [`Workload`] stepped directly through its
//!   [`WorkloadExec`](crate::workloads::WorkloadExec): the scheduler
//!   hands each slice a [`Fuel`] deadline and the algorithm preempts
//!   itself between loop iterations. Nothing is recorded — no O(ops)
//!   `Vec<Op>` pre-pass — so live multi-tenant runs work at `Full`
//!   scale, and the tenants are real algorithms, not passive access
//!   streams (the Angel et al., arXiv:1910.13056, critique).
//! * A trace tenant replays a recorded
//!   [`Trace`](crate::workloads::trace::Trace) through the identical
//!   stepper machinery (a [`TraceReplay`] cursor) — kept for external
//!   traces and frozen-access-pattern experiments.
//!
//! Either way every operation goes through the same
//! [`Engine`](crate::os::kernel) code the single-process facade uses.
//!
//! Determinism: scheduling order is fixed round-robin over the spawn
//! order, quanta are simulated-time bounds, and nothing consults host
//! state, so multi-tenant runs are bit-reproducible.
//!
//! # The sharded parallel engine
//!
//! [`ShardedCluster`] runs the same simulation on several worker
//! threads. The node slots are partitioned round-robin into `S` shards
//! (`node n -> shard n % S`), each shard owning a full [`ElasticCluster`]
//! whose kernel masks foreign slots as departed (empty pool, not live) —
//! so every existing placement/stretch/push/pull path confines a shard's
//! tenants to its own nodes with zero hot-path changes. Shards step
//! their tenants independently inside conservative time windows
//! (`[floor, floor + window)` on the shared [`WindowClock`]) and barrier
//! at window boundaries; membership churn crosses shards as
//! [`ShardMsg`] mail applied at barriers in canonical `(sender, seq)`
//! order. The *shard count* fixes the simulation semantics; the
//! *thread count* is pure host parallelism — for a fixed shard count,
//! results are bit-identical at any `--threads`, and tenant digests are
//! partition-independent (every digest must equal the tenant's
//! `DirectMem` ground truth regardless of contention or partition).

use crate::mem::addr::{NodeId, MAX_NODES};
use crate::os::kernel::{
    verify_cluster, ClusterConfig, Engine, EngineMem, NodeKernel, ProcSpec, ProcessCtx,
    ShardEnvelope, ShardMailbox, ShardMsg,
};
use crate::os::membership::{
    AppliedChurn, ChurnOp, ChurnSchedule, LeastLoaded, MembershipError, NodeCand, PlacementPolicy,
};
use crate::os::metrics::{Metrics, ShardStats};
use crate::os::policy::{JumpPolicy, ThresholdPolicy};
use crate::os::system::Mode;
use crate::sim::{LinkOp, LinkSchedule, SimClock, WindowClock};
use crate::workloads::trace::{Trace, TraceReplay};
use crate::workloads::{DirectMem, Fuel, StepOutcome, Workload, WorkloadExec};

/// Default scheduler quantum: 2 ms of simulated time (≈ a few dozen
/// remote faults' worth, so contention interleaves at fault granularity
/// without drowning the run in context switches).
pub const DEFAULT_QUANTUM_NS: u64 = 2_000_000;

/// Default conservative time window of the sharded engine: four quanta,
/// so a shard gets a few round-robin passes per barrier and the barrier
/// overhead amortizes, while churn latency (applied at barriers) stays
/// in the same order as the legacy engine's slice granularity.
pub const DEFAULT_WINDOW_NS: u64 = 4 * DEFAULT_QUANTUM_NS;

/// Per-process outcome of a multi-tenant run.
#[derive(Debug, Clone)]
pub struct ProcRunReport {
    pub pid: u32,
    /// Workload label supplied at spawn time (task_struct.comm).
    pub comm: String,
    pub mode: String,
    pub policy: String,
    /// Digest of the tenant's result — must equal its `DirectMem`
    /// ground truth.
    pub digest: u64,
    /// Simulated ns this process actively executed (its own compute,
    /// faults, and primitives; excludes time other tenants held the
    /// scheduler). This is the per-process execution time the
    /// multi-tenant experiment compares across modes.
    pub cpu_ns: u64,
    /// Shared-clock timestamp when the process finished (makespan-ish).
    pub finished_at_ns: u64,
    /// Paged memory operations executed (setup data-build included for
    /// live tenants; for traces this is the replayed op count).
    pub ops: u64,
    pub start_node: NodeId,
    pub metrics: Metrics,
}

/// What one tenant of a multi-tenant run executes.
pub enum TenantJob {
    /// A live algorithm, stepped under preemption — no recording pass,
    /// no O(ops) replay buffer.
    Live(Box<dyn Workload>),
    /// A recorded trace, replayed through the same stepper machinery
    /// (external traces / frozen access patterns).
    Trace(Trace),
}

impl TenantJob {
    /// The uniform form the scheduler drives: live workloads as
    /// themselves, traces as a [`TraceReplay`] cursor.
    fn into_workload(self) -> Box<dyn Workload> {
        match self {
            TenantJob::Live(w) => w,
            TenantJob::Trace(t) => Box::new(TraceReplay::new(t)),
        }
    }
}

/// One scheduled tenant: its in-flight exec plus completion bookkeeping.
struct Job {
    slot: usize,
    exec: Box<dyn WorkloadExec>,
    ops: u64,
    digest: Option<u64>,
    finished_at_ns: u64,
}

/// A cluster of nodes running N elasticized processes.
pub struct ElasticCluster {
    pub clock: SimClock,
    pub(crate) kernel: NodeKernel,
    pub(crate) procs: Vec<ProcessCtx>,
    /// Round-robin time slice in simulated ns.
    pub quantum_ns: u64,
    /// Placement policy consulted by `spawn_placed` (default:
    /// least-loaded-by-free-frames over live registry members).
    pub(crate) placement: Box<dyn PlacementPolicy>,
    /// Scripted membership changes, applied between time slices.
    pub(crate) churn: ChurnSchedule,
    /// Membership changes actually applied this run (with drain
    /// outcomes), in application order.
    pub churn_log: Vec<AppliedChurn>,
    /// Scripted link faults (cut / degrade / heal), applied between
    /// time slices alongside churn.
    pub(crate) link_faults: LinkSchedule,
    /// Link transitions actually applied this run, in application
    /// order, stamped with the sim time they took effect.
    pub link_log: Vec<(u64, LinkOp)>,
    /// Simulated time spent by the membership control plane (join
    /// announces, drain pushes, forced jumps) — cluster work no single
    /// process is charged for. With churn,
    /// `sum(cpu_ns) + churn_ns == clock.now()`.
    pub churn_ns: u64,
}

impl ElasticCluster {
    pub fn new(cfg: ClusterConfig) -> ElasticCluster {
        let clock = SimClock::new(cfg.costs.local_access_num, cfg.costs.local_access_den);
        ElasticCluster {
            clock,
            kernel: NodeKernel::new(cfg),
            procs: Vec::new(),
            quantum_ns: DEFAULT_QUANTUM_NS,
            placement: Box::new(LeastLoaded),
            churn: ChurnSchedule::default(),
            churn_log: Vec::new(),
            link_faults: LinkSchedule::default(),
            link_log: Vec::new(),
            churn_ns: 0,
        }
    }

    /// Spawn a process with the paper's threshold policy (or NeverJump
    /// in Nswap mode) on an explicit live home node. Returns its
    /// process-table slot; errs if the home node is out of range or
    /// departed. For announce-driven placement use
    /// [`Self::spawn_placed`](crate::os::membership).
    pub fn spawn(
        &mut self,
        mode: Mode,
        home: NodeId,
        comm: &str,
        threshold: u64,
    ) -> Result<usize, MembershipError> {
        self.spawn_with_policy(mode, home, comm, Box::new(ThresholdPolicy::new(threshold)))
    }

    /// Spawn a process with an explicit jumping policy.
    pub fn spawn_with_policy(
        &mut self,
        mode: Mode,
        home: NodeId,
        comm: &str,
        policy: Box<dyn JumpPolicy>,
    ) -> Result<usize, MembershipError> {
        if (home.0 as usize) >= self.kernel.node_count() {
            return Err(MembershipError::HomeOutOfRange {
                home,
                nodes: self.kernel.node_count(),
            });
        }
        if !self.kernel.is_live(home) {
            return Err(MembershipError::NodeDeparted(home));
        }
        if self.kernel.is_memory_server(home) {
            return Err(MembershipError::MemoryServerNode(home));
        }
        let slot = self.procs.len();
        self.procs.push(ProcessCtx::new(
            slot,
            ProcSpec { mode, home, comm: comm.to_string(), policy },
        ));
        Ok(slot)
    }

    pub fn proc_count(&self) -> usize {
        self.procs.len()
    }

    pub fn proc(&self, slot: usize) -> &ProcessCtx {
        &self.procs[slot]
    }

    /// Node *slots* (live and departed; ids are stable for the life of
    /// the cluster).
    pub fn node_count(&self) -> usize {
        self.kernel.node_count()
    }

    /// Is this node currently a live member?
    pub fn is_live(&self, node: NodeId) -> bool {
        self.kernel.is_live(node)
    }

    /// Number of live members.
    pub fn live_count(&self) -> usize {
        self.kernel.live_count()
    }

    pub fn free_frames(&self, node: NodeId) -> u32 {
        self.kernel.free_frames(node)
    }

    /// Cluster-wide consistency check (see `kernel::verify_cluster`).
    pub fn verify(&self) -> Result<(), String> {
        verify_cluster(&self.kernel, &self.procs)
    }

    /// Simulated wire time the batch/prefetch paths have saved so far
    /// versus per-page messages (0 with batching off).
    pub fn batch_saved_ns(&self) -> u64 {
        self.kernel.batch_wire_saved_ns
    }

    #[inline]
    fn engine(&mut self, cur: usize) -> Engine<'_> {
        Engine {
            kernel: &mut self.kernel,
            clock: &mut self.clock,
            procs: &mut self.procs,
            cur,
        }
    }

    /// One EOS-manager monitoring pass over the whole process table
    /// (the paper's Fig-3 loop): every process's counters are sampled
    /// against the cluster view and stretch directives applied. The
    /// scheduler calls the live-only variant so finished processes are
    /// no longer monitored (or charged).
    pub fn manager_pass(&mut self) {
        let all: Vec<usize> = (0..self.procs.len()).collect();
        self.manager_pass_for(&all);
    }

    pub(crate) fn manager_pass_for(&mut self, slots: &[usize]) {
        for &slot in slots {
            let t0 = self.clock.now();
            self.engine(slot).maybe_stretch();
            let dt = self.clock.now() - t0;
            // A stretch the monitor initiates is borne by that process.
            self.procs[slot].cpu_ns += dt;
        }
    }

    /// Run one recorded trace per (already-spawned) process to
    /// completion under round-robin time slicing (compatibility form of
    /// [`Self::run_jobs`]: every tenant is a trace cursor).
    pub fn run_concurrent(&mut self, jobs: Vec<(usize, Trace)>) -> Vec<ProcRunReport> {
        self.run_jobs(jobs.into_iter().map(|(slot, t)| (slot, TenantJob::Trace(t))).collect())
    }

    /// Run one *live* workload per (already-spawned) process: each
    /// algorithm is stepped under preemption directly — no recording
    /// pass, no O(ops) replay buffer.
    pub fn run_live(&mut self, jobs: Vec<(usize, Box<dyn Workload>)>) -> Vec<ProcRunReport> {
        self.run_jobs(jobs.into_iter().map(|(slot, w)| (slot, TenantJob::Live(w))).collect())
    }

    /// Run a mixed set of live and trace tenants to completion under
    /// round-robin time slicing, and report per process. `tenants`
    /// pairs each process slot with its job.
    pub fn run_jobs(&mut self, tenants: Vec<(usize, TenantJob)>) -> Vec<ProcRunReport> {
        let mut jobs = self.setup_jobs(tenants);
        // Round-robin scheduling loop, uncapped: rounds repeat until
        // every job is done.
        while self.round(&mut jobs, None) {}
        jobs.iter().map(|job| self.report_for(job)).collect()
    }

    /// Setup phase of a multi-tenant run, in spawn order at t≈0: each
    /// process maps its regions (and, live, builds its input data
    /// through the elastic pager), then hoists its execution state into
    /// a stepper.
    fn setup_jobs(&mut self, tenants: Vec<(usize, TenantJob)>) -> Vec<Job> {
        let mut jobs: Vec<Job> = Vec::with_capacity(tenants.len());
        for (slot, tenant) in tenants {
            let mut w = tenant.into_workload();
            let t0 = self.clock.now();
            let a0 = self.clock.accesses();
            let exec = {
                let mut mem = EngineMem { eng: self.engine(slot) };
                w.setup(&mut mem);
                w.start()
            };
            let now = self.clock.now();
            let setup_ops = self.clock.accesses() - a0;
            self.procs[slot].cpu_ns += now - t0;
            jobs.push(Job { slot, exec, ops: setup_ops, digest: None, finished_at_ns: 0 });
        }
        jobs
    }

    /// One scheduler round: apply due churn, give every unfinished job
    /// one quantum slice, then (if anything ran) one EOS-manager
    /// monitoring pass. Returns whether any job executed.
    ///
    /// `window_end` is the sharded engine's conservative cap: each
    /// slice's deadline is clamped to it and a job whose clock has
    /// already reached the cap is skipped, so a shard can never run
    /// past its window. `None` (the single-threaded engine) reproduces
    /// the legacy uncapped loop exactly.
    fn round(&mut self, jobs: &mut [Job], window_end: Option<u64>) -> bool {
        // Membership churn first: scripted joins/leaves due at the
        // current simulated time apply on the slice boundary, so a
        // process never observes the cluster changing mid-access
        // and churn runs stay bit-reproducible. Post-join manager
        // passes monitor only still-live tenants (exited ones are
        // neither monitored nor charged). A preempted stepper holds
        // only virtual addresses and scalar cursors, so it resumes
        // safely across drains and forced jumps.
        let live: Vec<usize> = jobs.iter().filter(|j| j.digest.is_none()).map(|j| j.slot).collect();
        self.apply_due_churn(&live);
        // Link faults apply on the same boundary: the fabric changes
        // between slices, never mid-access.
        self.apply_due_link_events();
        let quantum = self.quantum_ns.max(1);
        let mut ran_any = false;
        for job in jobs.iter_mut() {
            if job.digest.is_some() {
                continue;
            }
            let slice_start = self.clock.now();
            let mut deadline = slice_start + quantum;
            if let Some(cap) = window_end {
                if slice_start >= cap {
                    continue;
                }
                deadline = deadline.min(cap);
            }
            ran_any = true;
            let a0 = self.clock.accesses();
            let outcome = {
                let mut mem = EngineMem {
                    eng: Engine {
                        kernel: &mut self.kernel,
                        clock: &mut self.clock,
                        procs: &mut self.procs,
                        cur: job.slot,
                    },
                };
                job.exec.step(&mut mem, Fuel::until_ns(deadline))
            };
            let now = self.clock.now();
            job.ops += self.clock.accesses() - a0;
            self.procs[job.slot].cpu_ns += now - slice_start;
            if let StepOutcome::Done(digest) = outcome {
                job.digest = Some(digest);
                job.finished_at_ns = now;
            }
        }
        if ran_any {
            // The EOS manager's monitoring loop runs between slices,
            // watching the table of still-live processes (paper Fig 3);
            // exited tenants are neither monitored nor charged.
            let live: Vec<usize> =
                jobs.iter().filter(|j| j.digest.is_none()).map(|j| j.slot).collect();
            self.manager_pass_for(&live);
        }
        ran_any
    }

    fn report_for(&self, job: &Job) -> ProcRunReport {
        let p = &self.procs[job.slot];
        ProcRunReport {
            pid: p.pid,
            comm: p.meta.comm.clone(),
            mode: p.mode().as_str().to_string(),
            policy: p.policy_describe(),
            digest: job.digest.expect("scheduler loop runs every job to completion"),
            cpu_ns: p.cpu_ns,
            finished_at_ns: job.finished_at_ns,
            ops: job.ops,
            start_node: p.home(),
            metrics: p.metrics.clone(),
        }
    }
}

impl std::fmt::Debug for ElasticCluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ElasticCluster")
            .field("nodes", &self.kernel.node_count())
            .field("procs", &self.procs.len())
            .field("sim_ns", &self.clock.now())
            .finish()
    }
}

// ----- the sharded parallel engine ----------------------------------------

/// One shard of a [`ShardedCluster`]: a full [`ElasticCluster`] whose
/// kernel owns `node n where n % S == shard` (foreign slots are masked
/// as departed), plus its in-flight jobs and barrier mail. Whole shards
/// move between worker threads at window boundaries, which is why every
/// piece of tenant state is `Send`.
struct Shard {
    cluster: ElasticCluster,
    /// Tenants routed to this shard, awaiting the parallel setup phase.
    pending: Vec<(usize, TenantJob)>,
    /// In-flight jobs (local process-table slots), in setup order.
    jobs: Vec<Job>,
    /// Global process ids aligned with `jobs`.
    gids: Vec<usize>,
    mailbox: ShardMailbox,
    stats: ShardStats,
}

impl Shard {
    fn has_unfinished(&self) -> bool {
        self.jobs.iter().any(|j| j.digest.is_none())
    }

    /// Local process-table slots of still-running tenants (the monitor
    /// set for churn-triggered manager passes).
    fn live_job_slots(&self) -> Vec<usize> {
        self.jobs.iter().filter(|j| j.digest.is_none()).map(|j| j.slot).collect()
    }

    /// Step this shard's tenants up to `window_end` (the conservative
    /// cap): repeated scheduler rounds whose slices clamp to the window,
    /// until the local clock reaches the cap or every job is done.
    fn run_window(&mut self, window_end: u64) {
        if !self.has_unfinished() {
            return;
        }
        // lint: allow(determinism) reason=wall-clock ShardStats only; never feeds the sim clock
        let t0 = std::time::Instant::now();
        while self.cluster.clock.now() < window_end {
            if !self.cluster.round(&mut self.jobs, Some(window_end)) {
                break;
            }
        }
        self.stats.busy_ns += t0.elapsed().as_nanos() as u64;
        self.stats.windows += 1;
    }
}

/// The parallel simulation engine: the cluster's node slots are
/// partitioned round-robin into shards (`node n -> shard n % S`), each
/// shard stepping its resident tenants independently inside a
/// conservative time window, with a barrier on the shared
/// [`WindowClock`] at every window boundary.
///
/// Semantics vs. the single-threaded engine:
///
/// * **The shard count is the simulation's partition** — tenants place,
///   stretch, push and pull only within their shard's nodes, so a
///   sharded run is a legitimate (different) simulation of the same
///   cluster, not an approximation of the unsharded one. With one
///   shard the engine routes to [`ElasticCluster::run_jobs`] unchanged
///   and is bit-identical to the legacy engine.
/// * **The thread count is pure host parallelism** — for a fixed shard
///   count, digests, finish times, and every [`Metrics`] counter are
///   bit-identical at any `threads` value: shards only interact through
///   barrier mail applied in canonical `(sender, seq)` order, never
///   through the thread schedule.
/// * **Digests are partition-independent** — every tenant's digest must
///   equal its `DirectMem` ground truth at *any* shard count (the
///   repo's core invariant), which is what the determinism suite
///   checks across partitions.
///
/// Membership churn is global: the driver owns the [`ChurnSchedule`],
/// converts events due at the committed floor into [`ShardMsg`] mail
/// (a fresh node id is broadcast as a `SlotAppend` so every shard's
/// global slot indexing stays aligned, then `Join`/`Leave` go to the
/// owning shard), and applies inboxes at the barrier.
pub struct ShardedCluster {
    shards: Vec<Shard>,
    /// Worker threads driving the shards (clamped to the shard count;
    /// 1 = step shards sequentially on the caller's thread).
    pub threads: usize,
    /// The conservative window/barrier schedule.
    pub window: WindowClock,
    /// Placement policy for [`Self::spawn_placed`], consulted over the
    /// merged live membership of all shards.
    placement: Box<dyn PlacementPolicy>,
    /// Global scripted membership changes (driver-owned; shards get
    /// them as barrier mail).
    churn: ChurnSchedule,
    /// Membership changes actually applied, in application order.
    pub churn_log: Vec<AppliedChurn>,
    /// Global scripted link faults. Unlike churn there is no owning
    /// shard: link state is fabric-global (every shard's cost model
    /// prices the same links), so each due event is broadcast to all
    /// shards as barrier mail.
    link_faults: LinkSchedule,
    /// Link transitions actually applied, in application order.
    pub link_log: Vec<(u64, LinkOp)>,
    /// Global node-slot count (grows when churn appends a fresh slot).
    global_nodes: usize,
    /// Global process id -> (shard, local process-table slot).
    proc_map: Vec<(usize, usize)>,
    /// Control-plane mail sequence (the driver is sender `usize::MAX`).
    ctl_seq: u64,
}

impl ShardedCluster {
    /// Partition `cfg`'s nodes into `shards` shards driven by
    /// `threads` worker threads. Every shard must own at least one
    /// *peer* node, so `shards` may not exceed the peer count; memory
    /// servers (trailing slots) partition by the same `n % S` rule, so
    /// each shard's tenants demote to the far capacity it owns.
    pub fn new(cfg: ClusterConfig, shards: usize, threads: usize) -> ShardedCluster {
        assert!(shards >= 1, "need at least one shard");
        assert!(
            shards <= cfg.node_frames.len(),
            "cannot cut {} peer nodes into {} shards (every shard needs a live peer)",
            cfg.node_frames.len(),
            shards
        );
        let nodes = cfg.node_frames.len() + cfg.far_frames.len();
        let shard_vec = (0..shards)
            .map(|s| {
                let owned: Vec<bool> = (0..nodes).map(|n| n % shards == s).collect();
                Shard {
                    cluster: shard_cluster(&cfg, &owned),
                    pending: Vec::new(),
                    jobs: Vec::new(),
                    gids: Vec::new(),
                    mailbox: ShardMailbox::default(),
                    stats: ShardStats::default(),
                }
            })
            .collect();
        ShardedCluster {
            shards: shard_vec,
            threads: threads.max(1),
            window: WindowClock::new(DEFAULT_WINDOW_NS),
            placement: Box::new(LeastLoaded),
            churn: ChurnSchedule::default(),
            churn_log: Vec::new(),
            link_faults: LinkSchedule::default(),
            link_log: Vec::new(),
            global_nodes: nodes,
            proc_map: Vec::new(),
            ctl_seq: 0,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Global node-slot count (live and departed).
    pub fn node_count(&self) -> usize {
        self.global_nodes
    }

    /// Live members across all shards.
    pub fn live_count(&self) -> usize {
        self.shards.iter().map(|s| s.cluster.live_count()).sum()
    }

    pub fn proc_count(&self) -> usize {
        self.proc_map.len()
    }

    /// The process behind a global process id.
    pub fn proc(&self, gid: usize) -> &ProcessCtx {
        let (s, local) = self.proc_map[gid];
        &self.shards[s].cluster.procs[local]
    }

    /// The shard owning node `home` (and any process homed there).
    pub fn shard_of(&self, home: NodeId) -> usize {
        home.0 as usize % self.shards.len()
    }

    /// Processes resident on one shard.
    pub fn procs_on_shard(&self, s: usize) -> usize {
        self.proc_map.iter().filter(|&&(sh, _)| sh == s).count()
    }

    /// Set every shard's round-robin quantum.
    pub fn set_quantum(&mut self, quantum_ns: u64) {
        for shard in &mut self.shards {
            shard.cluster.quantum_ns = quantum_ns;
        }
    }

    /// Replace the barrier schedule (resets the floor; call before
    /// running).
    pub fn set_window(&mut self, window_ns: u64) {
        self.window = WindowClock::new(window_ns);
    }

    /// Swap the placement policy consulted by [`Self::spawn_placed`].
    pub fn set_placement(&mut self, policy: Box<dyn PlacementPolicy>) {
        self.placement = policy;
    }

    /// Install a global churn schedule; events become barrier mail once
    /// the committed floor passes their timestamps.
    pub fn set_churn(&mut self, schedule: ChurnSchedule) {
        self.churn = schedule;
    }

    /// Scripted churn events that never came due.
    pub fn churn_pending(&self) -> usize {
        self.churn.pending()
    }

    /// Install a link-fault schedule (driver-owned; shards receive due
    /// transitions as broadcast barrier mail).
    pub fn set_link_faults(&mut self, schedule: LinkSchedule) {
        self.link_faults = schedule;
    }

    /// Scripted link transitions that never came due.
    pub fn link_pending(&self) -> usize {
        self.link_faults.pending()
    }

    /// Suspicions raised across all shards: `(node, sim-ns)` pairs
    /// sorted by detection time — the partition eval's time-to-detect
    /// source.
    pub fn suspicion_log(&self) -> Vec<(u8, u64)> {
        let mut all: Vec<(u8, u64)> = self
            .shards
            .iter()
            .flat_map(|s| s.cluster.kernel.suspicion_log.iter().copied())
            .collect();
        all.sort_by_key(|&(n, t)| (t, n));
        all
    }

    /// The simulation's makespan so far: the furthest shard clock
    /// (every tenant's finish time is on its own shard's clock).
    pub fn sim_now(&self) -> u64 {
        self.shards.iter().map(|s| s.cluster.clock.now()).max().unwrap_or(0)
    }

    /// Simulated control-plane time across all shards.
    pub fn churn_ns(&self) -> u64 {
        self.shards.iter().map(|s| s.cluster.churn_ns).sum()
    }

    /// Simulated wire time saved by batching, across all shards.
    pub fn batch_saved_ns(&self) -> u64 {
        self.shards.iter().map(|s| s.cluster.batch_saved_ns()).sum()
    }

    /// Per-shard host utilization (busy vs. barrier wait), by shard id.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats).collect()
    }

    /// Cluster-wide consistency check, shard by shard.
    pub fn verify(&self) -> Result<(), String> {
        for (s, shard) in self.shards.iter().enumerate() {
            shard.cluster.verify().map_err(|e| format!("shard {s}: {e}"))?;
        }
        Ok(())
    }

    /// Spawn on an explicit home node (routed to the owning shard).
    /// Returns the *global* process id.
    pub fn spawn(
        &mut self,
        mode: Mode,
        home: NodeId,
        comm: &str,
        threshold: u64,
    ) -> Result<usize, MembershipError> {
        self.spawn_with_policy(mode, home, comm, Box::new(ThresholdPolicy::new(threshold)))
    }

    /// [`Self::spawn`] with an explicit jumping policy.
    pub fn spawn_with_policy(
        &mut self,
        mode: Mode,
        home: NodeId,
        comm: &str,
        policy: Box<dyn JumpPolicy>,
    ) -> Result<usize, MembershipError> {
        let s = self.shard_of(home);
        let local = self.shards[s].cluster.spawn_with_policy(mode, home, comm, policy)?;
        let gid = self.proc_map.len();
        // Rebrand the shard-local pid to the global process id, so
        // reports and logs stay unambiguous across shards.
        let pid = 1000 + gid as u32;
        let p = &mut self.shards[s].cluster.procs[local];
        p.pid = pid;
        p.meta.pid = pid;
        self.proc_map.push((s, local));
        Ok(gid)
    }

    /// Spawn with the placement policy choosing the home node from the
    /// merged live membership of all shards (paper §4: announce so
    /// others can pick). Which shard hosts the process follows from the
    /// picked home node.
    pub fn spawn_placed(
        &mut self,
        mode: Mode,
        comm: &str,
        threshold: u64,
    ) -> Result<usize, MembershipError> {
        let mut cands: Vec<NodeCand> = Vec::new();
        for shard in self.shards.iter_mut() {
            cands.extend(shard.cluster.placement_candidates());
        }
        cands.sort_by_key(|c| c.id.0);
        let home = self.placement.pick(&cands).ok_or(MembershipError::NoLiveNode)?;
        self.spawn(mode, home, comm, threshold)
    }

    /// [`Self::spawn_placed`] with an explicit jumping policy.
    pub fn spawn_placed_with_policy(
        &mut self,
        mode: Mode,
        comm: &str,
        policy: Box<dyn JumpPolicy>,
    ) -> Result<usize, MembershipError> {
        let mut cands: Vec<NodeCand> = Vec::new();
        for shard in self.shards.iter_mut() {
            cands.extend(shard.cluster.placement_candidates());
        }
        cands.sort_by_key(|c| c.id.0);
        let home = self.placement.pick(&cands).ok_or(MembershipError::NoLiveNode)?;
        self.spawn_with_policy(mode, home, comm, policy)
    }

    /// Run one live workload per (already-spawned) global process id.
    pub fn run_live(&mut self, jobs: Vec<(usize, Box<dyn Workload>)>) -> Vec<ProcRunReport> {
        self.run_jobs(jobs.into_iter().map(|(gid, w)| (gid, TenantJob::Live(w))).collect())
    }

    /// Run a mixed set of live and trace tenants to completion across
    /// all shards; reports come back in global process-id order.
    ///
    /// With one shard this routes to the legacy
    /// [`ElasticCluster::run_jobs`] (bit-identical to the
    /// single-threaded engine); otherwise the shards run the
    /// window/barrier protocol, on `threads` worker threads.
    pub fn run_jobs(&mut self, tenants: Vec<(usize, TenantJob)>) -> Vec<ProcRunReport> {
        if self.shards.len() == 1 {
            // One shard owns everything: hand the global churn schedule
            // to the inner cluster and run the unchanged legacy loop.
            let shard = &mut self.shards[0];
            shard.cluster.set_churn(std::mem::take(&mut self.churn));
            shard.cluster.set_link_faults(std::mem::take(&mut self.link_faults));
            let proc_map = &self.proc_map;
            let local: Vec<(usize, TenantJob)> =
                tenants.into_iter().map(|(gid, job)| (proc_map[gid].1, job)).collect();
            let reports = shard.cluster.run_jobs(local);
            // Reclaim the schedules (with their cursors) so
            // churn_pending/link_pending keep reporting events that
            // never came due.
            self.churn = std::mem::take(&mut shard.cluster.churn);
            self.churn_log.clone_from(&shard.cluster.churn_log);
            self.link_faults = std::mem::take(&mut shard.cluster.link_faults);
            self.link_log.clone_from(&shard.cluster.link_log);
            return reports;
        }

        // Route each tenant to its process's shard (preserving relative
        // order, so per-shard setup and scheduling order is the global
        // spawn order restricted to the shard).
        for (gid, job) in tenants {
            let (s, local) = self.proc_map[gid];
            self.shards[s].pending.push((local, job));
            self.shards[s].gids.push(gid);
        }

        // Setup phase: per-shard sequential (deterministic), shards in
        // parallel.
        let threads = self.threads;
        self.for_each_shard(threads, |shard| {
            let pending = std::mem::take(&mut shard.pending);
            shard.jobs = shard.cluster.setup_jobs(pending);
        });

        // The window/barrier loop.
        loop {
            let min_live = self
                .shards
                .iter()
                .filter(|s| s.has_unfinished())
                .map(|s| s.cluster.clock.now())
                .min();
            let Some(min_live) = min_live else { break };
            let window_end = self.window.open_window(min_live);
            // Churn due at the committed floor becomes barrier mail,
            // applied before any shard steps into the window — every
            // shard observes a membership change at the same boundary
            // regardless of the thread schedule.
            self.route_due_churn();
            self.route_due_links();
            self.apply_barrier_messages();

            let active: Vec<bool> = self.shards.iter().map(|s| s.has_unfinished()).collect();
            let busy0: Vec<u64> = self.shards.iter().map(|s| s.stats.busy_ns).collect();
            // lint: allow(determinism) reason=barrier-wait wall measurement; never feeds sim state
            let t0 = std::time::Instant::now();
            self.for_each_shard(threads, |shard| shard.run_window(window_end));
            let wall = t0.elapsed().as_nanos() as u64;
            for ((shard, b0), was_active) in self.shards.iter_mut().zip(busy0).zip(active) {
                if was_active {
                    let busy = shard.stats.busy_ns - b0;
                    shard.stats.barrier_wait_ns += wall.saturating_sub(busy);
                }
            }
        }

        // Reports in global process-id order.
        let mut tagged: Vec<(usize, ProcRunReport)> = Vec::new();
        for shard in &self.shards {
            for (j, job) in shard.jobs.iter().enumerate() {
                tagged.push((shard.gids[j], shard.cluster.report_for(job)));
            }
        }
        tagged.sort_by_key(|&(gid, _)| gid);
        tagged.into_iter().map(|(_, r)| r).collect()
    }

    /// Drive `f` over every shard: sequentially when one thread,
    /// otherwise on scoped worker threads over contiguous shard chunks.
    /// Each shard is owned by exactly one worker for the duration, so
    /// there is nothing to lock (and no poison to unwrap).
    fn for_each_shard<F>(&mut self, threads: usize, f: F)
    where
        F: Fn(&mut Shard) + Sync,
    {
        let threads = threads.clamp(1, self.shards.len());
        if threads == 1 {
            for shard in &mut self.shards {
                f(shard);
            }
            return;
        }
        let chunk = (self.shards.len() + threads - 1) / threads;
        let f = &f;
        std::thread::scope(|scope| {
            for shards in self.shards.chunks_mut(chunk) {
                scope.spawn(move || {
                    for shard in shards {
                        f(shard);
                    }
                });
            }
        });
    }

    /// Convert churn events due at the committed floor into barrier
    /// mail. A join of the next fresh node id first broadcasts a
    /// `SlotAppend` to every shard (global slot indexing stays aligned),
    /// then the owning shard gets the `Join`; leaves go straight to the
    /// owner. Structurally invalid events (id holes, overflow) are
    /// logged and skipped here; per-shard validity (already live, last
    /// live node) is judged by the owner at application time.
    fn route_due_churn(&mut self) {
        let floor = self.window.floor();
        while let Some(ev) = self.churn.pop_due(floor) {
            match ev.op {
                ChurnOp::Join { node, frames } => {
                    let slot = node as usize;
                    if slot >= MAX_NODES {
                        log::warn!(
                            "churn join of node{node} skipped: cluster already has the \
                             maximum of {MAX_NODES} node slots"
                        );
                        continue;
                    }
                    if slot > self.global_nodes {
                        log::warn!(
                            "churn join of node{node} skipped: would leave an id hole \
                             (next fresh slot is {})",
                            self.global_nodes
                        );
                        continue;
                    }
                    if slot == self.global_nodes {
                        for to in 0..self.shards.len() {
                            self.ctl_send(to, ev.at_ns, ShardMsg::SlotAppend { node });
                        }
                        self.global_nodes += 1;
                    }
                    let owner = slot % self.shards.len();
                    self.ctl_send(owner, ev.at_ns, ShardMsg::Join { node, frames });
                }
                ChurnOp::Leave { node } => {
                    let slot = node as usize;
                    if slot >= self.global_nodes {
                        log::warn!("churn leave of node{node} skipped: no such node");
                        continue;
                    }
                    let owner = slot % self.shards.len();
                    self.ctl_send(owner, ev.at_ns, ShardMsg::Leave { node });
                }
                ChurnOp::Crash { node } => {
                    let slot = node as usize;
                    if slot >= self.global_nodes {
                        log::warn!("churn crash of node{node} skipped: no such node");
                        continue;
                    }
                    let owner = slot % self.shards.len();
                    self.ctl_send(owner, ev.at_ns, ShardMsg::Crash { node });
                }
            }
        }
    }

    /// Convert link transitions due at the committed floor into
    /// barrier mail. Unlike churn there is no owning shard: link state
    /// is fabric-global (each shard's cost model prices the same
    /// ordered pairs), so every due event broadcasts to all shards.
    /// The driver's log is authoritative — shards applying barrier
    /// mail do not log, so `link_log` holds each transition once.
    fn route_due_links(&mut self) {
        let floor = self.window.floor();
        while let Some(ev) = self.link_faults.pop_due(floor) {
            let (a, b) = ev.op.pair();
            if a as usize >= self.global_nodes || b as usize >= self.global_nodes {
                log::warn!("link event node{a}~node{b} skipped: no such node");
                continue;
            }
            let state = ev.op.state();
            for to in 0..self.shards.len() {
                self.ctl_send(to, ev.at_ns, ShardMsg::Link { a, b, state });
            }
            self.link_log.push((ev.at_ns, ev.op));
        }
    }

    /// Deliver one control-plane message (the driver is sender
    /// `usize::MAX`, sequenced after every real shard).
    fn ctl_send(&mut self, to: usize, at_ns: u64, msg: ShardMsg) {
        let env = ShardEnvelope { from: usize::MAX, seq: self.ctl_seq, at_ns, msg };
        self.ctl_seq += 1;
        self.shards[to].mailbox.deliver([env]);
    }

    /// Apply every shard's inbox at the barrier, shards in id order and
    /// each inbox in canonical `(sender, seq)` order — one fixed global
    /// application order however many threads produced the messages.
    fn apply_barrier_messages(&mut self) {
        for s in 0..self.shards.len() {
            if self.shards[s].mailbox.inbox_is_empty() {
                continue;
            }
            for env in self.shards[s].mailbox.drain_inbox() {
                self.apply_msg(s, env);
            }
        }
    }

    fn apply_msg(&mut self, s: usize, env: ShardEnvelope) {
        let shard = &mut self.shards[s];
        let now = shard.cluster.clock.now().max(env.at_ns);
        match env.msg {
            ShardMsg::SlotAppend { node } => {
                // Idempotent: only append if this shard hasn't yet.
                if (node as usize) == shard.cluster.kernel.node_count() {
                    shard.cluster.kernel.append_dead_slot(node as usize);
                }
            }
            ShardMsg::Join { node, frames } => {
                let monitor = shard.live_job_slots();
                match shard.cluster.admit_node_for(NodeId(node), frames, &monitor) {
                    Ok(_) => self.churn_log.push(AppliedChurn {
                        at_ns: now,
                        op: ChurnOp::Join { node, frames },
                        drain: None,
                        crash: None,
                    }),
                    Err(e) => log::warn!("churn join of node{node} skipped: {e}"),
                }
            }
            ShardMsg::Leave { node } => match shard.cluster.retire_node(NodeId(node)) {
                Ok(drain) => self.churn_log.push(AppliedChurn {
                    at_ns: now,
                    op: ChurnOp::Leave { node },
                    drain: Some(drain),
                    crash: None,
                }),
                Err(e) => log::warn!("churn leave of node{node} skipped: {e}"),
            },
            ShardMsg::Crash { node } => match shard.cluster.crash_node(NodeId(node)) {
                Ok(crash) => self.churn_log.push(AppliedChurn {
                    at_ns: now,
                    op: ChurnOp::Crash { node },
                    drain: None,
                    crash: Some(crash),
                }),
                Err(e) => log::warn!("churn crash of node{node} skipped: {e}"),
            },
            ShardMsg::Link { a, b, state } => {
                // Driver already logged the transition (route_due_links);
                // the shard only updates its fabric view and, on a heal,
                // charges the announce that clears suspicion.
                shard.cluster.apply_link(a, b, state);
            }
        }
    }
}

impl std::fmt::Debug for ShardedCluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedCluster")
            .field("shards", &self.shards.len())
            .field("threads", &self.threads)
            .field("nodes", &self.global_nodes)
            .field("procs", &self.proc_map.len())
            .field("sim_ns", &self.sim_now())
            .finish()
    }
}

/// Build one shard's [`ElasticCluster`]: the full global slot layout
/// with only the owned slots armed (see [`NodeKernel::new_sharded`]).
fn shard_cluster(cfg: &ClusterConfig, owned: &[bool]) -> ElasticCluster {
    let clock = SimClock::new(cfg.costs.local_access_num, cfg.costs.local_access_den);
    ElasticCluster {
        clock,
        kernel: NodeKernel::new_sharded(cfg.clone(), owned),
        procs: Vec::new(),
        quantum_ns: DEFAULT_QUANTUM_NS,
        placement: Box::new(LeastLoaded),
        churn: ChurnSchedule::default(),
        churn_log: Vec::new(),
        link_faults: LinkSchedule::default(),
        link_log: Vec::new(),
        churn_ns: 0,
    }
}

/// `DirectMem` ground-truth digest for a live workload: one flat run,
/// nothing recorded, so peak extra allocation is the footprint itself
/// rather than an O(ops) `Vec<Op>` — this is what makes live
/// multi-tenant runs feasible at `Scale::Full`.
pub fn direct_ground_truth(workload: &mut dyn Workload) -> u64 {
    let mut mem = DirectMem::new();
    workload.setup(&mut mem);
    workload.run(&mut mem)
}

/// Record `workload` against flat memory and return its trace plus the
/// trace's `DirectMem` replay digest — the per-process ground truth a
/// contended *trace* replay must reproduce exactly. (Live tenants use
/// [`direct_ground_truth`] and skip the O(ops) recording entirely.)
pub fn record_ground_truth(workload: &mut dyn Workload) -> (Trace, u64) {
    let mut mem = DirectMem::new();
    let (trace, _workload_digest) = crate::workloads::trace::record(workload, &mut mem);
    let mut replay = TraceReplay::new(trace);
    let mut flat = DirectMem::new();
    replay.setup(&mut flat);
    let digest = replay.run(&mut flat);
    // Reclaim the trace without copying its O(ops) op stream: the
    // replay's exec cursors are gone, so the Arc is sole-owned again.
    let trace = std::sync::Arc::try_unwrap(replay.trace)
        .expect("replay execs are dropped before the trace is reclaimed");
    (trace, digest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{by_name, Scale};

    fn truth_and_trace(wl: &str, bytes: u64) -> (Trace, u64) {
        let mut w = by_name(wl, Scale::Bytes(bytes)).unwrap();
        record_ground_truth(w.as_mut())
    }

    #[test]
    fn two_procs_contend_and_match_ground_truth() {
        let (ta, da) = truth_and_trace("linear", 60 * 4096);
        let (tb, db) = truth_and_trace("count_sort", 60 * 4096);
        let cfg = ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        cluster.quantum_ns = 100_000; // force genuine interleaving at test scale
        let pa = cluster.spawn(Mode::Elastic, NodeId(0), "linear", 64).unwrap();
        let pb = cluster.spawn(Mode::Elastic, NodeId(1), "count_sort", 64).unwrap();
        let reports = cluster.run_concurrent(vec![(pa, ta), (pb, tb)]);
        assert_eq!(reports[0].digest, da, "proc A diverged from ground truth");
        assert_eq!(reports[1].digest, db, "proc B diverged from ground truth");
        cluster.verify().unwrap();
        // both actually consumed simulated time, and the shared clock
        // covers at least the larger of the two
        assert!(reports.iter().all(|r| r.cpu_ns > 0));
        let total: u64 = reports.iter().map(|r| r.cpu_ns).sum();
        assert_eq!(total, cluster.clock.now(), "slices must partition the shared clock");
    }

    #[test]
    fn contention_forces_stretch_of_individually_fitting_procs() {
        // Each process alone fits its home node comfortably; together
        // they overcommit node 0, so the shared-capacity manager rule
        // must stretch at least one of them.
        let cfg = ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        cluster.quantum_ns = 100_000;
        let mut jobs = Vec::new();
        for i in 0..3 {
            let (t, _) = truth_and_trace("linear", 60 * 4096);
            let slot = cluster.spawn(Mode::Elastic, NodeId(0), &format!("p{i}"), 64).unwrap();
            jobs.push((slot, t));
        }
        let reports = cluster.run_concurrent(jobs);
        let stretches: u64 = reports.iter().map(|r| r.metrics.stretches).sum();
        assert!(stretches > 0, "contention must trigger stretching");
        assert!(
            reports.iter().any(|r| r.metrics.pushes > 0 || r.metrics.remote_faults > 0),
            "contention must cause paging activity"
        );
        cluster.verify().unwrap();
    }

    #[test]
    fn spawn_rejects_bad_homes_instead_of_panicking() {
        use crate::os::membership::MembershipError;
        let cfg = ClusterConfig { node_frames: vec![64, 64], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        assert_eq!(
            cluster.spawn(Mode::Elastic, NodeId(5), "oops", 64),
            Err(MembershipError::HomeOutOfRange { home: NodeId(5), nodes: 2 })
        );
        // a departed node is named, not silently remapped
        cluster.retire_node(NodeId(1)).unwrap();
        assert_eq!(
            cluster.spawn(Mode::Elastic, NodeId(1), "oops", 64),
            Err(MembershipError::NodeDeparted(NodeId(1)))
        );
        assert!(cluster.spawn(Mode::Elastic, NodeId(0), "fine", 64).is_ok());
    }

    #[test]
    fn spawn_placed_spreads_over_live_members() {
        let cfg = ClusterConfig { node_frames: vec![64, 64, 64], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        let mut homes = Vec::new();
        for i in 0..6 {
            let slot = cluster
                .spawn_placed(Mode::Elastic, &format!("t{i}"), 64)
                .expect("placement on a live cluster");
            homes.push(cluster.proc(slot).home().0);
        }
        // least-loaded with equal free RAM spreads by homed count
        assert_eq!(homes, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn empty_trace_completes_immediately() {
        let cfg = ClusterConfig { node_frames: vec![64, 64], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        let slot = cluster.spawn(Mode::Elastic, NodeId(0), "idle", 64).unwrap();
        let reports = cluster.run_concurrent(vec![(slot, Trace::default())]);
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].ops, 0);
        cluster.verify().unwrap();
    }

    #[test]
    fn live_and_trace_tenants_mix_and_match_ground_truth() {
        // One frozen trace cursor and one live stepper contend on the
        // same cluster; both must reproduce their DirectMem truths.
        let (ta, da) = truth_and_trace("linear", 60 * 4096);
        let mut wb = by_name("count_sort", Scale::Bytes(60 * 4096)).unwrap();
        let db = direct_ground_truth(wb.as_mut());
        let cfg = ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };
        let mut cluster = ElasticCluster::new(cfg);
        cluster.quantum_ns = 100_000;
        let pa = cluster.spawn(Mode::Elastic, NodeId(0), "linear", 64).unwrap();
        let pb = cluster.spawn(Mode::Elastic, NodeId(1), "count_sort", 64).unwrap();
        let reports =
            cluster.run_jobs(vec![(pa, TenantJob::Trace(ta)), (pb, TenantJob::Live(wb))]);
        assert_eq!(reports[0].digest, da, "trace tenant diverged");
        assert_eq!(reports[1].digest, db, "live tenant diverged");
        assert!(reports.iter().all(|r| r.ops > 0 && r.cpu_ns > 0));
        cluster.verify().unwrap();
    }

    #[test]
    fn sharded_single_shard_is_bit_identical_to_legacy() {
        // shards=1 must route to the unchanged legacy engine: same
        // digests, same per-process times, same Metrics, same clock.
        let (ta, da) = truth_and_trace("linear", 60 * 4096);
        let (tb, db) = truth_and_trace("count_sort", 60 * 4096);
        let cfg = || ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };

        let mut legacy = ElasticCluster::new(cfg());
        legacy.quantum_ns = 100_000;
        let a = legacy.spawn(Mode::Elastic, NodeId(0), "linear", 64).unwrap();
        let b = legacy.spawn(Mode::Elastic, NodeId(1), "count_sort", 64).unwrap();
        let lr = legacy
            .run_jobs(vec![(a, TenantJob::Trace(ta.clone())), (b, TenantJob::Trace(tb.clone()))]);

        let mut sharded = ShardedCluster::new(cfg(), 1, 1);
        sharded.set_quantum(100_000);
        let ga = sharded.spawn(Mode::Elastic, NodeId(0), "linear", 64).unwrap();
        let gb = sharded.spawn(Mode::Elastic, NodeId(1), "count_sort", 64).unwrap();
        let sr = sharded.run_jobs(vec![(ga, TenantJob::Trace(ta)), (gb, TenantJob::Trace(tb))]);

        assert_eq!(lr.len(), sr.len());
        for (l, s) in lr.iter().zip(&sr) {
            assert_eq!(l.digest, s.digest);
            assert_eq!(l.cpu_ns, s.cpu_ns);
            assert_eq!(l.finished_at_ns, s.finished_at_ns);
            assert_eq!(l.ops, s.ops);
            assert_eq!(l.metrics, s.metrics);
            assert_eq!(l.pid, s.pid);
        }
        assert_eq!(sharded.sim_now(), legacy.clock.now());
        assert_eq!(sr[0].digest, da);
        assert_eq!(sr[1].digest, db);
        sharded.verify().unwrap();
    }

    #[test]
    fn sharded_two_shards_partition_and_match_ground_truth() {
        let (ta, da) = truth_and_trace("linear", 60 * 4096);
        let (tb, db) = truth_and_trace("count_sort", 60 * 4096);
        let cfg = ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };
        // two shards on two worker threads: exercises the scoped-thread
        // window loop
        let mut sharded = ShardedCluster::new(cfg, 2, 2);
        sharded.set_quantum(100_000);
        let ga = sharded.spawn(Mode::Elastic, NodeId(0), "linear", 64).unwrap();
        let gb = sharded.spawn(Mode::Elastic, NodeId(1), "count_sort", 64).unwrap();
        assert_eq!(sharded.shard_of(NodeId(0)), 0);
        assert_eq!(sharded.shard_of(NodeId(1)), 1);
        let reports =
            sharded.run_jobs(vec![(ga, TenantJob::Trace(ta)), (gb, TenantJob::Trace(tb))]);
        assert_eq!(reports[0].digest, da, "shard-0 tenant diverged from ground truth");
        assert_eq!(reports[1].digest, db, "shard-1 tenant diverged from ground truth");
        assert!(reports.iter().all(|r| r.cpu_ns > 0));
        sharded.verify().unwrap();
        // one tenant per shard: each shard's clock is exactly its
        // tenant's execution time, so the makespan is the slowest one
        assert_eq!(sharded.sim_now(), reports.iter().map(|r| r.cpu_ns).max().unwrap());
        // global pids stay unambiguous across shard-local tables
        assert_eq!(sharded.proc(ga).pid, 1000);
        assert_eq!(sharded.proc(gb).pid, 1001);
        let stats = sharded.stats();
        assert!(stats.iter().all(|s| s.windows > 0));
    }

    #[test]
    fn live_run_records_no_trace_and_matches_trace_run_digest() {
        // The same workload driven live and as a recorded trace must
        // land on the same digest (the access sequence is identical by
        // construction: run() is a start+step wrapper).
        let (trace, truth) = truth_and_trace("count_sort", 60 * 4096);
        let cfg = || ClusterConfig { node_frames: vec![96, 96], ..ClusterConfig::default() };

        let mut c1 = ElasticCluster::new(cfg());
        let s1 = c1.spawn(Mode::Elastic, NodeId(0), "cs", 64).unwrap();
        let trace_reports = c1.run_concurrent(vec![(s1, trace)]);

        let mut c2 = ElasticCluster::new(cfg());
        let s2 = c2.spawn(Mode::Elastic, NodeId(0), "cs", 64).unwrap();
        let w = by_name("count_sort", Scale::Bytes(60 * 4096)).unwrap();
        let live_reports = c2.run_live(vec![(s2, w)]);

        assert_eq!(trace_reports[0].digest, truth);
        assert_eq!(live_reports[0].digest, truth);
        assert_eq!(
            live_reports[0].ops, trace_reports[0].ops,
            "live stepping must issue exactly the ops the recording captured"
        );
        c1.verify().unwrap();
        c2.verify().unwrap();
    }
}
