//! Block sort (paper Table 1: "1.8 billion long int (13 GB)").
//!
//! A block merge sort: sort fixed-size blocks in place, then run
//! bottom-up merge passes through a scratch array.  Every pass is a
//! sequential sweep, so like linear search the pages form contiguous
//! LRU islands — the paper measured strong gains (threshold 512, ~12
//! jumps/sec).

use super::mem::{ElasticMem, U64Array};
use super::{fnv1a, Scale, Workload, FNV_SEED};
use crate::util::Rng;

/// Elements per block (64 KiB of u64s).
const BLOCK: u64 = 8192;

pub struct BlockSort {
    /// Element count; footprint is 2x (array + scratch).
    pub n: u64,
    seed: u64,
    arr: Option<U64Array>,
    scratch: Option<U64Array>,
}

impl BlockSort {
    pub fn new(scale: Scale) -> Self {
        BlockSort { n: (scale.bytes() / 16).max(16), seed: 0xB10C, arr: None, scratch: None }
    }
}

/// In-place insertion sort of arr[lo..hi) — used per block, where the
/// block is small and (after the first touch) page-local.
fn insertion_sort<M: ElasticMem + ?Sized>(mem: &mut M, arr: U64Array, lo: u64, hi: u64) {
    let mut i = lo + 1;
    while i < hi {
        let v = arr.get(mem, i);
        let mut j = i;
        while j > lo {
            let u = arr.get(mem, j - 1);
            if u <= v {
                break;
            }
            arr.set(mem, j, u);
            j -= 1;
        }
        arr.set(mem, j, v);
        i += 1;
    }
}

/// Iterative in-place quicksort (explicit interval stack, small-range
/// insertion fallback) over arr[lo..hi).
fn quicksort<M: ElasticMem + ?Sized>(mem: &mut M, arr: U64Array, lo: u64, hi: u64) {
    let mut stack = vec![(lo, hi)];
    while let Some((lo, hi)) = stack.pop() {
        if hi - lo <= 24 {
            insertion_sort(mem, arr, lo, hi);
            continue;
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (arr.get(mem, lo), arr.get(mem, mid), arr.get(mem, hi - 1));
        let pivot = a.max(b).min(a.min(b).max(c)); // median
        let mut i = lo;
        let mut j = hi - 1;
        loop {
            while arr.get(mem, i) < pivot {
                i += 1;
            }
            while arr.get(mem, j) > pivot {
                j -= 1;
            }
            if i >= j {
                break;
            }
            let (x, y) = (arr.get(mem, i), arr.get(mem, j));
            arr.set(mem, i, y);
            arr.set(mem, j, x);
            i += 1;
            if j == 0 {
                break;
            }
            j -= 1;
        }
        let split = i.max(lo + 1);
        stack.push((lo, split));
        stack.push((split, hi));
    }
}

impl Workload for BlockSort {
    fn set_seed(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn name(&self) -> &'static str {
        "block_sort"
    }

    fn footprint_bytes(&self) -> u64 {
        self.n * 16
    }

    fn setup(&mut self, mem: &mut dyn ElasticMem) {
        let arr = U64Array::map(mem, self.n, "bsort.arr");
        let scratch = U64Array::map(mem, self.n, "bsort.scratch");
        let mut rng = Rng::new(self.seed);
        for i in 0..self.n {
            arr.set(mem, i, rng.next_u64());
        }
        self.arr = Some(arr);
        self.scratch = Some(scratch);
    }

    fn run(&mut self, mem: &mut dyn ElasticMem) -> u64 {
        let mut src = self.arr.unwrap();
        let mut dst = self.scratch.unwrap();
        let n = self.n;

        // Phase 1: sort each block in place.
        let mut b = 0;
        while b < n {
            let hi = (b + BLOCK).min(n);
            quicksort(mem, src, b, hi);
            b += BLOCK;
        }

        // Phase 2: bottom-up merge passes, ping-ponging src <-> dst.
        let mut width = BLOCK;
        while width < n {
            let mut lo = 0;
            while lo < n {
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                // merge src[lo..mid] and src[mid..hi] into dst[lo..hi]
                let (mut i, mut j, mut k) = (lo, mid, lo);
                while i < mid && j < hi {
                    let (a, b) = (src.get(mem, i), src.get(mem, j));
                    if a <= b {
                        dst.set(mem, k, a);
                        i += 1;
                    } else {
                        dst.set(mem, k, b);
                        j += 1;
                    }
                    k += 1;
                }
                while i < mid {
                    let v = src.get(mem, i);
                    dst.set(mem, k, v);
                    i += 1;
                    k += 1;
                }
                while j < hi {
                    let v = src.get(mem, j);
                    dst.set(mem, k, v);
                    j += 1;
                    k += 1;
                }
                lo = hi;
            }
            std::mem::swap(&mut src, &mut dst);
            width *= 2;
        }

        // Digest: sortedness-sensitive hash over the final array.
        let mut digest = FNV_SEED;
        let mut prev = 0u64;
        let mut sorted = 1u64;
        for i in (0..n).step_by(7) {
            let v = src.get(mem, i);
            if v < prev {
                sorted = 0;
            }
            prev = v;
            digest = fnv1a(digest, v);
        }
        fnv1a(digest, sorted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mem::DirectMem;

    #[test]
    fn sorts_correctly() {
        let mut w = BlockSort::new(Scale::Bytes(512 * 1024));
        let mut m = DirectMem::new();
        w.setup(&mut m);
        let _ = w.run(&mut m);
        // after an even number of merge passes result is in arr or
        // scratch; verify whichever is sorted via full check on both
        let check = |m: &mut DirectMem, a: U64Array| -> bool {
            let mut prev = 0u64;
            for i in 0..a.len {
                let v = a.get(m, i);
                if v < prev {
                    return false;
                }
                prev = v;
            }
            true
        };
        let ok = check(&mut m, w.arr.unwrap()) || check(&mut m, w.scratch.unwrap());
        assert!(ok, "neither buffer is sorted");
    }

    #[test]
    fn quicksort_matches_std_sort() {
        let mut m = DirectMem::new();
        let arr = U64Array::map(&mut m, 5000, "t");
        let mut rng = crate::util::Rng::new(5);
        let mut expect: Vec<u64> = (0..5000).map(|_| rng.next_u64() % 1000).collect();
        for (i, &v) in expect.iter().enumerate() {
            arr.set(&mut m, i as u64, v);
        }
        quicksort(&mut m, arr, 0, 5000);
        expect.sort_unstable();
        for (i, &v) in expect.iter().enumerate() {
            assert_eq!(arr.get(&mut m, i as u64), v, "index {i}");
        }
    }

    #[test]
    fn insertion_sort_small() {
        let mut m = DirectMem::new();
        let arr = U64Array::map(&mut m, 10, "t");
        for (i, v) in [5u64, 3, 9, 1, 7, 2, 8, 0, 6, 4].iter().enumerate() {
            arr.set(&mut m, i as u64, *v);
        }
        insertion_sort(&mut m, arr, 0, 10);
        for i in 0..10 {
            assert_eq!(arr.get(&mut m, i), i);
        }
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut w = BlockSort::new(Scale::Bytes(256 * 1024));
            let mut m = DirectMem::new();
            w.setup(&mut m);
            w.run(&mut m)
        };
        assert_eq!(run(), run());
    }
}
