//! elastic-lint: static checks for the contracts the simulation's
//! correctness rests on.
//!
//! The sharded engine promises bit-identical results at any thread
//! count, every wire message must have a matching `CostModel` lane and
//! codec round-trip test, the `Pte` state machine has a small set of
//! legal transitions scattered across `os/`, and every `Metrics`
//! counter must actually reach a report. All of that is enforced here
//! by tooling instead of review:
//!
//! * `determinism` (R1) — no `HashMap`/`HashSet`, no
//!   `Instant`/`SystemTime`/`thread_rng`, and no float accumulation in
//!   the simulation-path modules (`os/`, `mem/`, `sim/`).
//! * `unsafe-safety` (R1) — every `unsafe` block in the tree carries a
//!   `// SAFETY:` comment.
//! * `protocol` (R2) — every `Msg` variant has a contiguous tag, a
//!   decode arm, a declared `CostModel` pricing method that exists in
//!   `sim/costs.rs`, and a codec test referencing it.
//! * `pte-transition` (R3) — every PTE state write site in `os/` sits
//!   inside the function the declared transition table allows.
//! * `metrics` (R4) — every `Metrics` counter is updated somewhere,
//!   surfaced in a summary/bench writer, and never mutated from two
//!   unrelated files without being declared shared.
//!
//! Escape hatch: a `// lint: allow(<rule>) reason=<why>` comment on the
//! flagged line (or in the comment block directly above it) suppresses
//! a finding; suppressed findings are counted and reported, and an
//! allow without a reason is itself a finding (`allow-syntax`).
//!
//! Implementation note: the offline build environment has no `syn` (or
//! any crates.io access), so this is a deliberately self-contained
//! line/token-level scanner: comments and string literals are stripped
//! before matching, and a brace-tracking pass recovers the enclosing
//! `fn` name for every line (all R3 needs). That is cruder than a real
//! AST, but the tree is rustfmt-formatted, which keeps the token
//! stream line-oriented enough for these rules to be exact in
//! practice — and the fixture tests below pin the behavior.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Declared rule tables
// ---------------------------------------------------------------------------

/// R2: `Msg` variant -> `CostModel` method that prices it. A variant
/// missing here fails the lint until its lane is declared — exactly
/// the "new tag, forgotten lane" mistake this rule exists to catch.
/// Control traffic (announces, membership, completion) rides the plain
/// `wire_ns` lane; checkpoints and page movement have dedicated lanes.
const MSG_LANES: &[(&str, &str)] = &[
    ("Hello", "wire_ns"),
    ("Stretch", "stretch_ns"),
    ("StretchAck", "stretch_ns"),
    ("Push", "push_ns"),
    ("PullReq", "pull_ns"),
    ("PullData", "pull_ns"),
    ("Jump", "jump_ns"),
    ("Sync", "wire_ns"),
    ("Done", "wire_ns"),
    ("Bye", "wire_ns"),
    ("Join", "wire_ns"),
    ("Leave", "wire_ns"),
    ("Drain", "wire_ns"),
    ("PushBatch", "push_batch_ns"),
    ("PullBatchReq", "pull_batch_ns"),
    ("PullBatchData", "pull_batch_ns"),
    ("DemoteBatch", "demote_batch_ns"),
    ("PromoteReq", "promote_batch_ns"),
    ("PromoteData", "promote_batch_ns"),
    ("DemoteRepl", "demote_batch_ns"),
    ("Crash", "wire_ns"),
    ("Suspect", "wire_ns"),
    ("HealLink", "wire_ns"),
];

/// R3: PTE state-write pattern -> functions allowed to perform it.
/// Everything else touching these transitions is a finding: the state
/// machine (unmapped -> resident -> far, plus the prefetched/pinned
/// bits) must stay confined to its named paths.
const PTE_TRANSITIONS: &[(&str, &[&str], &str)] = &[
    (".pt.map(", &["minor_fault"], "unmapped->resident only on first touch"),
    (".pt.relocate(", &["move_page", "pull_page"], "resident pages move only via the page movers"),
    (".pt.demote(", &["demote_page"], "resident->far only via demote_page"),
    (".pt.promote(", &["promote_page"], "far->resident only via promote_page"),
    (
        ".pt.unmap(",
        &["drain_lose", "crash_lose"],
        "live pages are unmapped only when a drain or a crash loses them",
    ),
    (
        ".pt.rehome_far(",
        &["crash_memory_server", "prefer_reachable_replica"],
        "far pages re-home only on replica fail-over (server crash) or when promotion \
         prefers the replica behind the cheapest live link",
    ),
    (
        ".set_prefetched(true)",
        &["prefetch_adjacent", "promote_adjacent"],
        "the prefetched bit is set only on speculative cold installs",
    ),
    (
        ".set_prefetched(false)",
        &["resolve_slow"],
        "the prefetched bit is consumed only by the first-touch slow path",
    ),
    (".set_pinned(true)", &["minor_fault"], "pages pin only when a stack page is first mapped"),
    (".set_pinned(false)", &[], "nothing unpins pages today; extend the table when that changes"),
];

/// R4: `Metrics` fields that may legitimately be mutated from more
/// than one file. Currently none — churn counters live in
/// `os/membership.rs`, everything else in `os/kernel.rs` or the
/// metrics module itself.
const METRICS_SHARED_OK: &[&str] = &[];

/// R4: files that count as surfacing a counter (summaries and bench
/// JSON writers). `os/metrics.rs` itself also counts, but only below
/// the struct declaration (i.e. in `total_bytes`/`summary_line`).
const METRICS_SURFACE_FILES: &[&str] = &["main.rs", "eval/experiments.rs", "eval/report.rs"];

/// R1 scope: module prefixes (relative to `rust/src/`) whose code
/// feeds simulated state and therefore must be deterministic.
const SIM_SCOPES: &[&str] = &["os/", "mem/", "sim/"];

// ---------------------------------------------------------------------------
// Data model
// ---------------------------------------------------------------------------

/// One source file, path relative to `rust/src/` (forward slashes).
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub snippet: String,
    pub msg: String,
}

/// A finding suppressed by a `// lint: allow(...)` comment.
#[derive(Debug, Clone)]
pub struct AllowedFinding {
    pub finding: Finding,
    pub reason: String,
}

/// Full result of a lint run.
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub allowed: Vec<AllowedFinding>,
}

#[derive(Debug, Clone)]
struct AllowSite {
    line: usize,
    rule: String,
    reason: String,
    reason_ok: bool,
}

/// Preprocessed file: raw lines, comment/string-stripped lines, the
/// enclosing fn name per line, and parsed allow comments.
struct Prepared {
    path: String,
    raw: Vec<String>,
    stripped: Vec<String>,
    fn_at: Vec<String>,
    allows: Vec<AllowSite>,
}

// ---------------------------------------------------------------------------
// Loading
// ---------------------------------------------------------------------------

/// Load every `.rs` file under `<repo_root>/rust/src`, sorted by path.
pub fn load_tree(repo_root: &Path) -> io::Result<Vec<SourceFile>> {
    let src = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &src, &mut files)?;
    files.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(files)
}

fn collect_rs(base: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(base, &p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = match p.strip_prefix(base) {
                Ok(r) => r.to_string_lossy().replace('\\', "/"),
                Err(_) => p.to_string_lossy().replace('\\', "/"),
            };
            out.push(SourceFile { path: rel, text: fs::read_to_string(&p)? });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Preprocessing
// ---------------------------------------------------------------------------

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Strip comments and the contents of string/char literals, keeping
/// the line structure intact so line numbers still correspond.
fn strip_source(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_block = 0usize;
    for line in text.lines() {
        let b: Vec<char> = line.chars().collect();
        let mut s = String::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            if in_block > 0 {
                if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                    in_block -= 1;
                    i += 2;
                } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    in_block += 1;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            let c = b[i];
            if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
                break; // line comment: drop the rest
            }
            if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                in_block += 1;
                i += 2;
                continue;
            }
            if c == '"' {
                s.push('"');
                i += 1;
                while i < b.len() {
                    if b[i] == '\\' {
                        i += 2;
                        continue;
                    }
                    if b[i] == '"' {
                        s.push('"');
                        i += 1;
                        break;
                    }
                    i += 1;
                }
                continue;
            }
            if c == '\'' {
                // Char literal vs lifetime: a literal closes within two
                // chars or starts with an escape.
                if i + 1 < b.len() && b[i + 1] == '\\' {
                    s.push('\'');
                    i += 2;
                    while i < b.len() && b[i] != '\'' {
                        i += 1;
                    }
                    i += 1;
                } else if i + 2 < b.len() && b[i + 2] == '\'' {
                    s.push('\'');
                    i += 3;
                } else {
                    s.push('\'');
                    i += 1; // lifetime marker
                }
                continue;
            }
            s.push(c);
            i += 1;
        }
        out.push(s);
    }
    out
}

/// Extract the function name declared on this (stripped) line, if any.
fn find_fn_name(line: &str) -> Option<String> {
    let b: Vec<char> = line.chars().collect();
    let mut i = 0;
    while i + 2 < b.len() {
        if b[i] == 'f'
            && b[i + 1] == 'n'
            && (i == 0 || !is_ident_char(b[i - 1]))
            && b[i + 2] == ' '
        {
            let mut j = i + 3;
            while j < b.len() && b[j] == ' ' {
                j += 1;
            }
            let start = j;
            while j < b.len() && is_ident_char(b[j]) {
                j += 1;
            }
            if j > start {
                return Some(b[start..j].iter().collect());
            }
        }
        i += 1;
    }
    None
}

/// For each line, the name of the innermost enclosing `fn` ("" when
/// outside any function), recovered by brace tracking.
fn fn_names(stripped: &[String]) -> Vec<String> {
    let mut out = Vec::with_capacity(stripped.len());
    let mut stack: Vec<(String, i32)> = Vec::new();
    let mut depth: i32 = 0;
    let mut pending: Option<String> = None;
    for line in stripped {
        if let Some(name) = find_fn_name(line) {
            pending = Some(name);
        }
        let here = match &pending {
            Some(n) => n.clone(),
            None => stack.last().map(|(n, _)| n.clone()).unwrap_or_default(),
        };
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                if let Some(n) = pending.take() {
                    stack.push((n, depth));
                }
            } else if ch == '}' {
                if stack.last().map(|&(_, d)| d) == Some(depth) {
                    stack.pop();
                }
                depth -= 1;
            }
        }
        out.push(here);
    }
    out
}

/// Parse `// lint: allow(<rule>) reason=<why>` comments (raw lines).
fn parse_allows(raw: &[String]) -> Vec<AllowSite> {
    let mut out = Vec::new();
    for (i, line) in raw.iter().enumerate() {
        let Some(pos) = line.find("lint: allow(") else { continue };
        let rest = &line[pos + "lint: allow(".len()..];
        let Some(end) = rest.find(')') else { continue };
        let rule = rest[..end].trim().to_string();
        let after = &rest[end + 1..];
        let (reason, reason_ok) = match after.find("reason=") {
            Some(rp) => {
                let r = after[rp + "reason=".len()..].trim().to_string();
                let ok = r.len() >= 3;
                (r, ok)
            }
            None => (String::new(), false),
        };
        out.push(AllowSite { line: i + 1, rule, reason, reason_ok });
    }
    out
}

fn prepare(f: &SourceFile) -> Prepared {
    let raw: Vec<String> = f.text.lines().map(|l| l.to_string()).collect();
    let stripped = strip_source(&f.text);
    let fn_at = fn_names(&stripped);
    let allows = parse_allows(&raw);
    Prepared { path: f.path.clone(), raw, stripped, fn_at, allows }
}

/// Find an allow for `rule` covering 1-based `line`: on the line
/// itself, or in the contiguous comment/attribute block above it.
fn find_allow<'a>(prep: &'a Prepared, rule: &str, line: usize) -> Option<&'a AllowSite> {
    let hit = |l: usize| prep.allows.iter().find(|a| a.line == l && a.rule == rule);
    if let Some(a) = hit(line) {
        return Some(a);
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let t = prep.raw[l - 1].trim();
        if t.is_empty() || t.starts_with("//") || t.starts_with("#[") {
            if let Some(a) = hit(l) {
                return Some(a);
            }
        } else {
            break;
        }
    }
    None
}

/// Whether the `unsafe` at 1-based `line` is covered by a `// SAFETY:`
/// comment: on the line itself, or above it within the same statement
/// (the walk stops at the previous statement or block boundary).
fn has_safety_comment(prep: &Prepared, line: usize) -> bool {
    let mut l = line;
    loop {
        if prep.raw[l - 1].contains("SAFETY:") {
            return true;
        }
        if l != line {
            let t = prep.raw[l - 1].trim();
            let code = &prep.stripped[l - 1];
            let commentish = t.is_empty() || t.starts_with("//") || t.starts_with("#[");
            if !commentish && (code.contains(';') || code.contains('{') || code.contains('}')) {
                return false;
            }
        }
        if l == 1 {
            return false;
        }
        l -= 1;
    }
}

/// Substring match with identifier boundaries on both sides.
fn has_word(line: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(p) = line[start..].find(word) {
        let at = start + p;
        let before_ok = at == 0 || !is_ident_char(line[..at].chars().next_back().unwrap());
        let after = at + word.len();
        let after_ok = after >= line.len() || !is_ident_char(line[after..].chars().next().unwrap());
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

fn snippet(prep: &Prepared, line: usize) -> String {
    let s = prep.raw.get(line - 1).map(|l| l.trim().to_string()).unwrap_or_default();
    if s.len() > 120 {
        let mut cut = 120;
        while cut > 0 && !s.is_char_boundary(cut) {
            cut -= 1;
        }
        format!("{}...", &s[..cut])
    } else {
        s
    }
}

fn finding(rule: &'static str, prep: &Prepared, line: usize, msg: String) -> Finding {
    Finding { rule, file: prep.path.clone(), line, snippet: snippet(prep, line), msg }
}

// ---------------------------------------------------------------------------
// R1: determinism + unsafe-safety
// ---------------------------------------------------------------------------

fn in_sim_scope(path: &str) -> bool {
    SIM_SCOPES.iter().any(|s| path.starts_with(s))
}

fn check_determinism(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in preps.iter().filter(|p| in_sim_scope(&p.path)) {
        for (i, line) in p.stripped.iter().enumerate() {
            let ln = i + 1;
            if has_word(line, "HashMap") || has_word(line, "HashSet") {
                out.push(finding(
                    "determinism",
                    p,
                    ln,
                    "hash collection in a simulation path: iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet or sorted iteration"
                        .to_string(),
                ));
            }
            if has_word(line, "Instant")
                || has_word(line, "SystemTime")
                || has_word(line, "thread_rng")
            {
                out.push(finding(
                    "determinism",
                    p,
                    ln,
                    "wall clock / ambient randomness in a simulation path: results \
                     must be a function of the seed and the cost model alone"
                        .to_string(),
                ));
            }
            let accum = line.contains("+=") || line.contains(".sum()") || line.contains(".fold(");
            if accum && (has_word(line, "f64") || has_word(line, "f32")) {
                out.push(finding(
                    "determinism",
                    p,
                    ln,
                    "float accumulation in a simulation path: rounding depends on \
                     evaluation order; use integer arithmetic or add an allow"
                        .to_string(),
                ));
            }
        }
    }
    out
}

fn check_unsafe(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in preps {
        for (i, line) in p.stripped.iter().enumerate() {
            let ln = i + 1;
            if has_word(line, "unsafe") && !has_safety_comment(p, ln) {
                out.push(finding(
                    "unsafe-safety",
                    p,
                    ln,
                    "unsafe without a `// SAFETY:` comment explaining why the \
                     invariants hold"
                        .to_string(),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R2: protocol completeness
// ---------------------------------------------------------------------------

/// Variants of `pub enum Msg` with their 1-based declaration lines.
fn enum_variants(prep: &Prepared) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    let n = prep.stripped.len();
    while i < n && !prep.stripped[i].contains("pub enum Msg") {
        i += 1;
    }
    if i == n {
        return out;
    }
    let mut depth = 0i32;
    let mut started = false;
    while i < n {
        let line = &prep.stripped[i];
        let depth_at_start = depth;
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                started = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if started && depth_at_start == 1 {
            let t = line.trim();
            if t.chars().next().map(|c| c.is_ascii_uppercase()).unwrap_or(false) {
                let name: String = t.chars().take_while(|c| is_ident_char(*c)).collect();
                out.push((name, i + 1));
            }
        }
        if started && depth == 0 {
            break;
        }
        i += 1;
    }
    out
}

/// `Msg::Name { .. } => N` arms inside the given function.
fn msg_match_arms(prep: &Prepared, func: &str) -> Vec<(String, u32, usize)> {
    let mut out = Vec::new();
    for (i, line) in prep.stripped.iter().enumerate() {
        if prep.fn_at[i] != func {
            continue;
        }
        let Some(p) = line.find("Msg::") else { continue };
        let name: String = line[p + 5..].chars().take_while(|c| is_ident_char(*c)).collect();
        let Some(ap) = line.find("=>") else { continue };
        if name.is_empty() {
            continue;
        }
        let digits: String = if ap > p {
            // `Msg::Name ... => N` (the tag() shape)
            line[ap + 2..].chars().filter(|c| c.is_ascii_digit()).collect()
        } else {
            // `N => Msg::Name ...` (the decode() shape)
            line[..ap].chars().filter(|c| c.is_ascii_digit()).collect()
        };
        if let Ok(v) = digits.parse::<u32>() {
            out.push((name, v, i + 1));
        }
    }
    out
}

/// First line index (0-based) of the `#[cfg(test)]` region, if any.
fn test_region_start(prep: &Prepared) -> Option<usize> {
    prep.raw.iter().position(|l| l.contains("#[cfg(test)]"))
}

fn check_protocol(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(proto) = preps.iter().find(|p| p.path == "net/proto.rs") else {
        return vec![Finding {
            rule: "protocol",
            file: "net/proto.rs".to_string(),
            line: 1,
            snippet: String::new(),
            msg: "net/proto.rs not found: cannot check protocol completeness".to_string(),
        }];
    };
    let variants = enum_variants(proto);
    if variants.is_empty() {
        out.push(finding("protocol", proto, 1, "no `pub enum Msg` variants parsed".to_string()));
        return out;
    }
    let tags = msg_match_arms(proto, "tag");
    let decodes = msg_match_arms(proto, "decode");
    let costs = preps.iter().find(|p| p.path == "sim/costs.rs");
    let tests_at = test_region_start(proto);

    let tag_of: BTreeMap<&str, u32> = tags.iter().map(|(n, v, _)| (n.as_str(), *v)).collect();
    let decoded: BTreeSet<u32> = decodes.iter().map(|(_, v, _)| *v).collect();

    // Tags must be unique and contiguous from 0.
    let mut seen_tags: BTreeSet<u32> = BTreeSet::new();
    for (name, v, line) in &tags {
        if !seen_tags.insert(*v) {
            out.push(finding(
                "protocol",
                proto,
                *line,
                format!("duplicate wire tag {v} (variant {name})"),
            ));
        }
    }
    for (i, v) in seen_tags.iter().enumerate() {
        if *v != i as u32 {
            out.push(finding(
                "protocol",
                proto,
                1,
                format!("wire tags are not contiguous: expected {i}, found {v}"),
            ));
            break;
        }
    }

    for (name, line) in &variants {
        let Some(tag) = tag_of.get(name.as_str()) else {
            out.push(finding("protocol", proto, *line, format!("variant {name} has no wire tag")));
            continue;
        };
        if !decoded.contains(tag) {
            out.push(finding(
                "protocol",
                proto,
                *line,
                format!("variant {name} (tag {tag}) has no decode arm"),
            ));
        }
        // Priced: a declared lane whose method exists in sim/costs.rs.
        match MSG_LANES.iter().find(|(n, _)| n == name) {
            None => out.push(finding(
                "protocol",
                proto,
                *line,
                format!(
                    "unpriced variant {name}: declare its CostModel lane in \
                     elastic-lint's MSG_LANES table"
                ),
            )),
            Some((_, method)) => {
                let needle = format!("fn {method}(");
                let exists =
                    costs.map(|c| c.stripped.iter().any(|l| l.contains(&needle))).unwrap_or(false);
                if !exists {
                    out.push(finding(
                        "protocol",
                        proto,
                        *line,
                        format!(
                            "variant {name} is priced by CostModel::{method}, which \
                             does not exist in sim/costs.rs"
                        ),
                    ));
                }
            }
        }
        // Tested: referenced in the codec test module. `has_word` gives
        // the name an identifier boundary, so `Msg::PushBatch` in a test
        // does not count as coverage for `Push`.
        let needle = format!("Msg::{name}");
        let covered = |l: &String| l.contains(&needle) && has_word(l, name);
        let tested = match tests_at {
            Some(start) => proto.stripped.iter().skip(start).any(covered),
            None => false,
        };
        if !tested {
            out.push(finding(
                "protocol",
                proto,
                *line,
                format!("variant {name} never appears in net/proto.rs codec tests"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R3: PTE transition table
// ---------------------------------------------------------------------------

fn check_pte(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in preps.iter().filter(|p| p.path.starts_with("os/")) {
        for (i, line) in p.stripped.iter().enumerate() {
            for (pat, allowed_fns, why) in PTE_TRANSITIONS {
                if !line.contains(pat) {
                    continue;
                }
                let here = p.fn_at[i].as_str();
                if !allowed_fns.contains(&here) {
                    out.push(finding(
                        "pte-transition",
                        p,
                        i + 1,
                        format!(
                            "PTE transition `{pat}` in fn `{here}` is outside the \
                             declared table ({why}); allowed: {allowed_fns:?}"
                        ),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R4: metrics accounting
// ---------------------------------------------------------------------------

/// `pub <name>: u64` fields of `pub struct Metrics`, plus the 1-based
/// line where the struct's declaration block ends.
fn metrics_fields(prep: &Prepared) -> (Vec<(String, usize)>, usize) {
    let mut out = Vec::new();
    let mut i = 0;
    let n = prep.stripped.len();
    while i < n && !prep.stripped[i].contains("pub struct Metrics") {
        i += 1;
    }
    if i == n {
        return (out, 0);
    }
    let mut depth = 0i32;
    let mut started = false;
    while i < n {
        let line = &prep.stripped[i];
        let depth_at_start = depth;
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                started = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if started && depth_at_start == 1 {
            let t = line.trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if rest.contains(": u64") {
                    let name: String = rest.chars().take_while(|c| is_ident_char(*c)).collect();
                    if !name.is_empty() {
                        out.push((name, i + 1));
                    }
                }
            }
        }
        if started && depth == 0 {
            return (out, i + 1);
        }
        i += 1;
    }
    (out, n)
}

/// Files whose code mutates `.field` via `+=` or `=` (not `==`).
/// Mutations inside a `#[cfg(test)]` region do not count — tests are
/// not a subsystem, and counters they poke still need a real owner.
fn mutation_files(preps: &[Prepared], field: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let dotted = format!(".{field}");
    for p in preps {
        let tests_at = test_region_start(p).unwrap_or(usize::MAX);
        for (i, line) in p.stripped.iter().enumerate() {
            if i >= tests_at {
                break;
            }
            let mut start = 0;
            let mut hit = false;
            while let Some(pos) = line[start..].find(&dotted) {
                let at = start + pos;
                let after = at + dotted.len();
                start = after;
                if after < line.len() && is_ident_char(line[after..].chars().next().unwrap()) {
                    continue; // longer identifier, e.g. .jumps_total
                }
                let rest = line[after..].trim_start();
                if rest.starts_with("+=") || (rest.starts_with('=') && !rest.starts_with("==")) {
                    hit = true;
                }
            }
            if hit {
                out.push((p.path.clone(), i + 1));
            }
        }
    }
    out
}

fn check_metrics(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(m) = preps.iter().find(|p| p.path == "os/metrics.rs") else {
        return vec![Finding {
            rule: "metrics",
            file: "os/metrics.rs".to_string(),
            line: 1,
            snippet: String::new(),
            msg: "os/metrics.rs not found: cannot check metrics accounting".to_string(),
        }];
    };
    let (fields, struct_end) = metrics_fields(m);
    if fields.is_empty() {
        out.push(finding("metrics", m, 1, "no `pub struct Metrics` u64 fields parsed".into()));
        return out;
    }
    for (field, line) in &fields {
        let sites = mutation_files(preps, field);
        if sites.is_empty() {
            out.push(finding(
                "metrics",
                m,
                *line,
                format!("Metrics::{field} is never updated anywhere in the tree"),
            ));
        }
        let files: BTreeSet<&str> = sites.iter().map(|(f, _)| f.as_str()).collect();
        if files.len() > 1 && !METRICS_SHARED_OK.contains(&field.as_str()) {
            out.push(finding(
                "metrics",
                m,
                *line,
                format!(
                    "Metrics::{field} is mutated from {} files ({:?}); one subsystem \
                     should own each counter — declare it in METRICS_SHARED_OK if \
                     the split is intentional",
                    files.len(),
                    files
                ),
            ));
        }
        let in_surface = preps.iter().any(|p| {
            METRICS_SURFACE_FILES.contains(&p.path.as_str())
                && p.stripped.iter().any(|l| has_word(l, field))
        });
        let in_metrics_impl =
            m.stripped.iter().enumerate().any(|(i, l)| i + 1 > struct_end && has_word(l, field));
        if !in_surface && !in_metrics_impl {
            out.push(finding(
                "metrics",
                m,
                *line,
                format!(
                    "Metrics::{field} is counted but never surfaced in a summary or \
                     bench-JSON writer ({METRICS_SURFACE_FILES:?} or the Metrics impl)"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Allow hygiene
// ---------------------------------------------------------------------------

fn check_allow_syntax(preps: &[Prepared]) -> Vec<Finding> {
    let mut out = Vec::new();
    let known: BTreeSet<&str> =
        ["determinism", "unsafe-safety", "protocol", "pte-transition", "metrics"]
            .into_iter()
            .collect();
    for p in preps {
        for a in &p.allows {
            if !a.reason_ok {
                out.push(finding(
                    "allow-syntax",
                    p,
                    a.line,
                    "lint allow without a reason: write \
                     `// lint: allow(<rule>) reason=<why>`"
                        .to_string(),
                ));
            }
            if !known.contains(a.rule.as_str()) {
                out.push(finding(
                    "allow-syntax",
                    p,
                    a.line,
                    format!("lint allow names unknown rule `{}`", a.rule),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver + rendering
// ---------------------------------------------------------------------------

/// Run every rule over the file set and apply the allow escape hatch.
pub fn check(files: &[SourceFile]) -> Report {
    let preps: Vec<Prepared> = files.iter().map(prepare).collect();
    let mut raw: Vec<Finding> = Vec::new();
    raw.extend(check_determinism(&preps));
    raw.extend(check_unsafe(&preps));
    raw.extend(check_protocol(&preps));
    raw.extend(check_pte(&preps));
    raw.extend(check_metrics(&preps));
    raw.extend(check_allow_syntax(&preps));
    raw.sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule)));

    let mut findings = Vec::new();
    let mut allowed = Vec::new();
    for f in raw {
        let covered = preps
            .iter()
            .find(|p| p.path == f.file)
            .and_then(|p| find_allow(p, f.rule, f.line))
            .filter(|a| a.reason_ok)
            .map(|a| a.reason.clone());
        match covered {
            Some(reason) => allowed.push(AllowedFinding { finding: f, reason }),
            None => findings.push(f),
        }
    }
    Report { files_scanned: preps.len(), findings, allowed }
}

fn rule_counts<'a, I: Iterator<Item = &'a Finding>>(it: I) -> BTreeMap<&'static str, usize> {
    let mut m = BTreeMap::new();
    for f in it {
        *m.entry(f.rule).or_insert(0) += 1;
    }
    m
}

/// Human-readable report.
pub fn render_text(report: &Report) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "elastic-lint: {} files scanned, {} finding(s), {} allowed\n",
        report.files_scanned,
        report.findings.len(),
        report.allowed.len()
    ));
    if !report.findings.is_empty() {
        let counts = rule_counts(report.findings.iter());
        let per: Vec<String> = counts.iter().map(|(r, n)| format!("{r}={n}")).collect();
        s.push_str(&format!("findings by rule: {}\n\n", per.join(" ")));
        for f in &report.findings {
            s.push_str(&format!("[{}] {}:{}: {}\n", f.rule, f.file, f.line, f.msg));
            if !f.snippet.is_empty() {
                s.push_str(&format!("    {}\n", f.snippet));
            }
        }
    }
    if !report.allowed.is_empty() {
        let counts = rule_counts(report.allowed.iter().map(|a| &a.finding));
        let per: Vec<String> = counts.iter().map(|(r, n)| format!("{r}={n}")).collect();
        s.push_str(&format!("\nallowed ({}): {}\n", report.allowed.len(), per.join(" ")));
        for a in &report.allowed {
            s.push_str(&format!(
                "[{}] {}:{}: allowed, reason={}\n",
                a.finding.rule, a.finding.file, a.finding.line, a.reason
            ));
        }
    }
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn finding_json(f: &Finding) -> String {
    format!(
        "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"msg\":\"{}\",\"snippet\":\"{}\"}}",
        f.rule,
        json_escape(&f.file),
        f.line,
        json_escape(&f.msg),
        json_escape(&f.snippet)
    )
}

/// Machine-readable report (the CI artifact). Hand-rolled like every
/// other JSON writer in this tree — serde is not available offline.
pub fn render_json(report: &Report) -> String {
    let findings: Vec<String> = report.findings.iter().map(finding_json).collect();
    let allowed: Vec<String> = report
        .allowed
        .iter()
        .map(|a| {
            let f = finding_json(&a.finding);
            // splice the reason into the object
            format!("{},\"reason\":\"{}\"}}", &f[..f.len() - 1], json_escape(&a.reason))
        })
        .collect();
    format!(
        "{{\n  \"files_scanned\": {},\n  \"findings\": [{}],\n  \"allowed\": [{}],\n  \
         \"counts\": {{\"findings\": {}, \"allowed\": {}}}\n}}\n",
        report.files_scanned,
        findings.join(","),
        allowed.join(","),
        report.findings.len(),
        report.allowed.len()
    )
}

// ---------------------------------------------------------------------------
// Fixture tests: each rule must catch a seeded violation.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn src(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.to_string(), text: text.to_string() }
    }

    fn of<'a>(report: &'a Report, rule: &str) -> Vec<&'a Finding> {
        report.findings.iter().filter(|f| f.rule == rule).collect()
    }

    #[test]
    fn determinism_catches_hash_collections_in_sim_paths() {
        let files = vec![src(
            "os/bad.rs",
            r#"
use std::collections::HashMap;
fn walk(m: &HashMap<u32, u32>) -> u32 {
    m.values().sum()
}
"#,
        )];
        let r = check(&files);
        assert_eq!(of(&r, "determinism").len(), 2, "{}", render_text(&r));
    }

    #[test]
    fn determinism_ignores_out_of_scope_and_comments_and_strings() {
        let files = vec![
            src("net/ok.rs", "use std::collections::HashMap;\n"),
            src(
                "os/ok.rs",
                "// a HashMap would be wrong here\nfn f() -> &'static str {\n    \
                 \"Instant HashMap\"\n}\n",
            ),
        ];
        let r = check(&files);
        assert!(of(&r, "determinism").is_empty(), "{}", render_text(&r));
    }

    #[test]
    fn determinism_catches_wall_clock_and_float_accumulation() {
        let files = vec![src(
            "sim/bad.rs",
            r#"
fn f(xs: &[f64]) -> f64 {
    let t = std::time::Instant::now();
    let mut acc = 0.0f64;
    acc += xs[0] as f64;
    let _ = t;
    acc
}
"#,
        )];
        let r = check(&files);
        assert_eq!(of(&r, "determinism").len(), 2, "{}", render_text(&r));
    }

    #[test]
    fn allow_suppresses_and_counts_with_reason() {
        let files = vec![src(
            "os/allowed.rs",
            "// lint: allow(determinism) reason=point lookups only, never iterated\n\
             use std::collections::HashMap;\n",
        )];
        let r = check(&files);
        assert!(of(&r, "determinism").is_empty(), "{}", render_text(&r));
        assert_eq!(r.allowed.len(), 1);
        assert!(r.allowed[0].reason.contains("point lookups"));
    }

    #[test]
    fn allow_without_reason_is_a_finding_and_does_not_suppress() {
        let files = vec![src(
            "os/noreason.rs",
            "// lint: allow(determinism)\nuse std::collections::HashSet;\n",
        )];
        let r = check(&files);
        assert_eq!(of(&r, "determinism").len(), 1, "{}", render_text(&r));
        assert_eq!(of(&r, "allow-syntax").len(), 1, "{}", render_text(&r));
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = src(
            "mem/bad.rs",
            "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        );
        let good = src(
            "mem/good.rs",
            "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller passes a valid pointer.\n    \
             unsafe { *p }\n}\n",
        );
        let r = check(&[bad, good]);
        let u = of(&r, "unsafe-safety");
        assert_eq!(u.len(), 1, "{}", render_text(&r));
        assert_eq!(u[0].file, "mem/bad.rs");
    }

    const PROTO_FIXTURE: &str = r#"
pub enum Msg {
    Hello { node: u8 },
    Zorp,
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 0,
            Msg::Zorp => 1,
        }
    }
    pub fn decode(tag: u8) -> Option<Msg> {
        let m = match tag {
            0 => Msg::Hello { node: 0 },
            1 => Msg::Zorp,
            _ => return None,
        };
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn round_trips() {
        let _ = super::Msg::Hello { node: 1 };
        let _ = super::Msg::Zorp;
    }
}
"#;

    const COSTS_FIXTURE: &str = "impl CostModel {\n    pub fn wire_ns(&self, b: u64) -> u64 {\n        \
                                 b\n    }\n}\n";

    #[test]
    fn protocol_catches_unpriced_variant() {
        // `Zorp` is not in MSG_LANES: declaring the lane is exactly the
        // step this rule forces on whoever adds a message.
        let r = check(&[src("net/proto.rs", PROTO_FIXTURE), src("sim/costs.rs", COSTS_FIXTURE)]);
        let p = of(&r, "protocol");
        assert_eq!(p.len(), 1, "{}", render_text(&r));
        assert!(p[0].msg.contains("unpriced variant Zorp"), "{}", p[0].msg);
    }

    #[test]
    fn protocol_catches_missing_lane_method() {
        // Hello's lane (wire_ns) is missing from this costs.rs.
        let costs = src("sim/costs.rs", "impl CostModel {\n    pub fn other(&self) {}\n}\n");
        let r = check(&[src("net/proto.rs", PROTO_FIXTURE), costs]);
        assert!(
            of(&r, "protocol").iter().any(|f| f.msg.contains("wire_ns")),
            "{}",
            render_text(&r)
        );
    }

    #[test]
    fn protocol_catches_untested_and_undecoded_variants() {
        let proto = r#"
pub enum Msg {
    Hello { node: u8 },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 0,
        }
    }
    pub fn decode(tag: u8) -> Option<Msg> {
        let _ = tag;
        None
    }
}

#[cfg(test)]
mod tests {}
"#;
        let r = check(&[src("net/proto.rs", proto), src("sim/costs.rs", COSTS_FIXTURE)]);
        let msgs: Vec<&str> = of(&r, "protocol").iter().map(|f| f.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("no decode arm")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("never appears")), "{msgs:?}");
    }

    #[test]
    fn protocol_catches_tag_gaps() {
        let proto = r#"
pub enum Msg {
    Hello { node: u8 },
    Bye,
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 0,
            Msg::Bye => 2,
        }
    }
    pub fn decode(tag: u8) -> Option<Msg> {
        let m = match tag {
            0 => Msg::Hello { node: 0 },
            2 => Msg::Bye,
            _ => return None,
        };
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = (super::Msg::Hello { node: 0 }, super::Msg::Bye);
    }
}
"#;
        let r = check(&[src("net/proto.rs", proto), src("sim/costs.rs", COSTS_FIXTURE)]);
        assert!(
            of(&r, "protocol").iter().any(|f| f.msg.contains("not contiguous")),
            "{}",
            render_text(&r)
        );
    }

    #[test]
    fn pte_transition_outside_declared_path_is_caught() {
        let bad = src(
            "os/rogue.rs",
            "impl K {\n    fn steal_page(&mut self) {\n        self.procs[0].pt.map(1, n, f);\n    \
             }\n}\n",
        );
        let good = src(
            "os/fault.rs",
            "impl K {\n    fn minor_fault(&mut self) {\n        self.procs[0].pt.map(1, n, f);\n    \
             }\n}\n",
        );
        let r = check(&[bad, good]);
        let p = of(&r, "pte-transition");
        assert_eq!(p.len(), 1, "{}", render_text(&r));
        assert_eq!(p[0].file, "os/rogue.rs");
        assert!(p[0].msg.contains("steal_page"));
    }

    #[test]
    fn pte_prefetched_bit_only_on_cold_install() {
        let bad = src(
            "os/rogue.rs",
            "impl K {\n    fn kswapd(&mut self) {\n        \
             self.procs[0].pt.get_mut(1).set_prefetched(true);\n    }\n}\n",
        );
        let r = check(&[bad]);
        assert_eq!(of(&r, "pte-transition").len(), 1, "{}", render_text(&r));
    }

    const METRICS_FIXTURE: &str = r#"
pub struct Metrics {
    pub used: u64,
    pub orphan: u64,
    pub hidden: u64,
}

impl Metrics {
    pub fn summary(&self) -> u64 {
        self.used
    }
}
"#;

    #[test]
    fn metrics_rule_catches_orphan_hidden_and_shared_counters() {
        let files = vec![
            src("os/metrics.rs", METRICS_FIXTURE),
            // `used` mutated from two unrelated files; `hidden` is
            // counted but surfaced nowhere; `orphan` never mutated.
            src("os/a.rs", "fn a(m: &mut Metrics) {\n    m.used += 1;\n    m.hidden += 1;\n}\n"),
            src("os/b.rs", "fn b(m: &mut Metrics) {\n    m.used += 1;\n}\n"),
        ];
        let r = check(&files);
        let msgs: Vec<&str> = of(&r, "metrics").iter().map(|f| f.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("orphan is never updated")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("hidden is counted but never")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("used is mutated from 2 files")), "{msgs:?}");
    }

    #[test]
    fn metrics_assignment_counts_as_update_but_comparison_does_not() {
        let files = vec![
            src(
                "os/metrics.rs",
                "pub struct Metrics {\n    pub set_once: u64,\n}\n\nimpl Metrics {\n    \
                 pub fn summary(&self) -> u64 {\n        self.set_once\n    }\n}\n",
            ),
            src(
                "os/k.rs",
                "fn k(m: &mut Metrics) {\n    m.set_once = 7;\n    if m.set_once == 7 {}\n}\n",
            ),
        ];
        let r = check(&files);
        assert!(of(&r, "metrics").is_empty(), "{}", render_text(&r));
    }

    #[test]
    fn fn_tracking_handles_nested_braces() {
        let stripped = strip_source(
            "fn outer(x: u32) -> u32 {\n    if x > 0 {\n        inner()\n    } else {\n        \
             0\n    }\n}\nfn later() {}\n",
        );
        let names = fn_names(&stripped);
        assert_eq!(names[2], "outer");
        assert_eq!(names[4], "outer");
        assert_eq!(names[7], "later");
    }

    #[test]
    fn json_report_is_escaped_and_counts_match() {
        let files = vec![src("os/bad.rs", "fn f() {\n    let m: HashMap<u8, \"x\\\"y\"> = 0;\n}\n")];
        let r = check(&files);
        let js = render_json(&r);
        assert!(js.contains("\"findings\""));
        assert!(js.contains("determinism"));
        assert!(js.contains(&format!("\"findings\": {}", r.findings.len())));
    }
}
