//! Property-based tests over the coordinator's invariants, using the
//! in-repo testkit (proptest is unavailable offline — see DESIGN.md
//! §3).  Seeds are printed on failure and replayable via
//! ELASTICOS_PROPTEST_SEED.

use elastic_os::mem::addr::AreaKind;
use elastic_os::mem::NodeId;
use elastic_os::os::system::{ElasticSystem, Mode, SystemConfig};
use elastic_os::proc::{apply_event, ProcessMeta, SyncEvent, SyncQueue};
use elastic_os::testkit::{gen, Runner};
use elastic_os::util::Rng;
use elastic_os::workloads::ElasticMem;

fn sys_with(frames: Vec<u32>, mode: Mode, threshold: u64) -> ElasticSystem {
    ElasticSystem::new(SystemConfig { node_frames: frames, mode, ..SystemConfig::default() }, threshold)
}

/// Random mixes of reads/writes/jumps keep every structural invariant:
/// page table counters == pool usage == LRU membership, no frame
/// aliasing, and all data reads back exactly.
#[test]
fn prop_random_access_preserves_invariants_and_data() {
    Runner::new("random_access").with_cases(24).run(|rng: &mut Rng| {
        let nodes = 2 + rng.below_usize(2); // 2..=3 nodes
        let frames = 48 + rng.below(48) as u32;
        let threshold = 8 + rng.below(64);
        let mode = if rng.chance(0.3) { Mode::Nswap } else { Mode::Elastic };
        let mut sys = sys_with(vec![frames; nodes], mode, threshold);

        // feasible footprint: up to ~80% of total cluster frames
        let total = frames as u64 * nodes as u64;
        let pages = total * 3 / 5 + rng.below(total / 5);
        let a = sys.mmap(pages * 4096, AreaKind::Heap, "prop");
        // shadow model of the data
        let mut shadow: Vec<u64> = vec![0; pages as usize];

        for _ in 0..4000 {
            let p = rng.below(pages);
            let addr = a + p * 4096 + (rng.below(512)) * 8;
            if rng.chance(0.5) {
                let v = rng.next_u64();
                sys.write_u64(addr, v);
                // track only the first word per page in the shadow to
                // keep the model simple
                if addr == a + p * 4096 {
                    shadow[p as usize] = v;
                }
            } else {
                let _ = sys.read_u64(addr);
            }
        }
        sys.verify().expect("structural invariants");
        // every tracked word reads back
        for (p, &v) in shadow.iter().enumerate() {
            if v != 0 {
                assert_eq!(sys.read_u64(a + p as u64 * 4096), v, "page {p}");
            }
        }
    });
}

/// Wherever execution is, after any run: resident page counts never
/// exceed pool capacities, and free+used == capacity.
#[test]
fn prop_frame_accounting_exact() {
    Runner::new("frame_accounting").with_cases(16).run(|rng: &mut Rng| {
        let frames = 64 + rng.below(64) as u32;
        let mut sys = sys_with(vec![frames, frames], Mode::Elastic, 16 + rng.below(100));
        let pages = frames as u64 + rng.below(frames as u64 / 2);
        let a = sys.mmap(pages * 4096, AreaKind::Heap, "acct");
        for p in 0..pages {
            sys.write_u64(a + p * 4096, p);
        }
        for node in 0..2u8 {
            let n = NodeId(node);
            assert!(sys.resident_at(n) <= frames);
            assert_eq!(sys.resident_at(n) + sys.free_frames(n), frames);
        }
        sys.verify().unwrap();
    });
}

/// The digest of a workload is identical across modes, thresholds,
/// node counts, and RAM sizes (execution correctness is placement-
/// independent).
#[test]
fn prop_digest_placement_independent() {
    Runner::new("digest_independence").with_cases(10).run(|rng: &mut Rng| {
        let wl = gen::one_of(rng, &["linear", "count_sort", "dfs"]);
        let footprint = 60 * 4096 + rng.below(40) * 4096;
        let reference = {
            let mut w = elastic_os::workloads::by_name(wl, elastic_os::workloads::Scale::Bytes(footprint)).unwrap();
            let mut mem = elastic_os::workloads::DirectMem::new();
            w.setup(&mut mem);
            w.run(&mut mem)
        };
        let nodes = 2 + rng.below_usize(2);
        // size the cluster so the footprint (plus guard/stack slack)
        // always fits: >= 0.75x footprint pages per node for 2 nodes
        let need = (footprint / 4096) as u32;
        let frames = need * 3 / 4 + rng.below(60) as u32;
        let threshold = 8 + rng.below(512);
        let mode = if rng.chance(0.5) { Mode::Nswap } else { Mode::Elastic };
        let mut w = elastic_os::workloads::by_name(wl, elastic_os::workloads::Scale::Bytes(footprint)).unwrap();
        let mut sys = sys_with(vec![frames; nodes], mode, threshold);
        let r = sys.run_workload(w.as_mut());
        assert_eq!(r.digest, reference, "{wl} diverged (mode {mode:?}, frames {frames}, nodes {nodes})");
    });
}

/// Traffic accounting identity: total bytes == pulls*(req+page) +
/// pushes*page + jump/stretch/sync checkpoint bytes (no bytes appear
/// or vanish unaccounted).
#[test]
fn prop_traffic_accounting_consistent() {
    Runner::new("traffic_accounting").with_cases(12).run(|rng: &mut Rng| {
        let frames = 48 + rng.below(64) as u32;
        let mut sys = sys_with(vec![frames, frames], Mode::Elastic, 8 + rng.below(64));
        let pages = frames as u64 * 3 / 2;
        let a = sys.mmap(pages * 4096, AreaKind::Heap, "traffic");
        for _ in 0..3000 {
            let p = rng.below(pages);
            sys.write_u64(a + p * 4096, p);
        }
        let m = &sys.metrics;
        let page_msg = 4096 + 13; // Push/PullData wire size (tag+idx+len+frame)
        let pull_req = 9; // PullReq wire size
        assert_eq!(m.bytes_pull, m.remote_faults * (page_msg + pull_req), "pull bytes");
        assert_eq!(m.bytes_push, m.pushes * page_msg, "push bytes");
        // jumps carry at least the register file + framing
        assert!(m.jumps == 0 || m.bytes_jump / m.jumps >= 200);
    });
}

/// One randomly generated memory operation, applied to mirrored
/// memories through the bulk API on one and the equivalent scalar loop
/// on the other (the loop each bulk default impl documents).
enum MemOp {
    /// (addr, element width in bytes, per-element values)
    Write(u64, u64, Vec<u64>),
    /// (addr, element width, element count)
    Read(u64, u64, u64),
    /// (addr, element count, value)
    Fill(u64, u64, u64),
    /// (dst, src, element width, element count) — ranges disjoint
    Copy(u64, u64, u64, u64),
}

fn apply_bulk(mem: &mut dyn ElasticMem, op: &MemOp, out: &mut Vec<u64>) {
    out.clear();
    match op {
        MemOp::Write(addr, 1, vals) => {
            let bytes: Vec<u8> = vals.iter().map(|&v| v as u8).collect();
            mem.write_bytes(*addr, &bytes);
        }
        MemOp::Write(addr, 4, vals) => {
            let words: Vec<u32> = vals.iter().map(|&v| v as u32).collect();
            mem.write_u32s(*addr, &words);
        }
        MemOp::Write(addr, _, vals) => mem.write_u64s(*addr, vals),
        MemOp::Read(addr, 1, n) => {
            let mut bytes = vec![0u8; *n as usize];
            mem.read_bytes(*addr, &mut bytes);
            out.extend(bytes.iter().map(|&b| b as u64));
        }
        MemOp::Read(addr, 4, n) => {
            let mut words = vec![0u32; *n as usize];
            mem.read_u32s(*addr, &mut words);
            out.extend(words.iter().map(|&w| w as u64));
        }
        MemOp::Read(addr, _, n) => {
            let mut words = vec![0u64; *n as usize];
            mem.read_u64s(*addr, &mut words);
            out.extend_from_slice(&words);
        }
        MemOp::Fill(addr, n, v) => mem.fill_u64(*addr, *n, *v),
        MemOp::Copy(dst, src, 1, n) => mem.copy(*dst, *src, *n),
        MemOp::Copy(dst, src, _, n) => mem.copy_u64s(*dst, *src, *n),
    }
}

fn apply_scalar(mem: &mut dyn ElasticMem, op: &MemOp, out: &mut Vec<u64>) {
    out.clear();
    match op {
        MemOp::Write(addr, 1, vals) => {
            for (i, &v) in vals.iter().enumerate() {
                mem.write_u8(addr + i as u64, v as u8);
            }
        }
        MemOp::Write(addr, 4, vals) => {
            for (i, &v) in vals.iter().enumerate() {
                mem.write_u32(addr + i as u64 * 4, v as u32);
            }
        }
        MemOp::Write(addr, _, vals) => {
            for (i, &v) in vals.iter().enumerate() {
                mem.write_u64(addr + i as u64 * 8, v);
            }
        }
        MemOp::Read(addr, 1, n) => out.extend((0..*n).map(|i| mem.read_u8(addr + i) as u64)),
        MemOp::Read(addr, 4, n) => {
            out.extend((0..*n).map(|i| mem.read_u32(addr + i * 4) as u64))
        }
        MemOp::Read(addr, _, n) => out.extend((0..*n).map(|i| mem.read_u64(addr + i * 8))),
        MemOp::Fill(addr, n, v) => {
            for i in 0..*n {
                mem.write_u64(addr + i * 8, *v);
            }
        }
        MemOp::Copy(dst, src, 1, n) => {
            for i in 0..*n {
                let v = mem.read_u8(src + i);
                mem.write_u8(dst + i, v);
            }
        }
        MemOp::Copy(dst, src, _, n) => {
            for i in 0..*n {
                let v = mem.read_u64(src + 8 * i);
                mem.write_u64(dst + 8 * i, v);
            }
        }
    }
}

/// Generate one op over a region of `bytes` bytes at `base`: random
/// width, random span (regularly crossing page boundaries), copies
/// confined to disjoint halves.
fn gen_op(rng: &mut Rng, base: u64, bytes: u64) -> MemOp {
    let elem = [1u64, 4, 8][rng.below_usize(3)];
    // spans up to ~3 pages, always leaving room inside the region
    let max_n = (3 * 4096 / elem).min(bytes / (2 * elem) - 1);
    let n = 1 + rng.below(max_n);
    let span = n * elem;
    match rng.below(4) {
        0 => {
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let a = base + (rng.below(bytes - span) / elem) * elem;
            MemOp::Write(a, elem, vals)
        }
        1 => {
            let a = base + (rng.below(bytes - span) / elem) * elem;
            MemOp::Read(a, elem, n)
        }
        2 => {
            // fills are u64-wide regardless of the drawn width
            let n = n.min(bytes / 16 - 1).max(1);
            let a = base + (rng.below(bytes - n * 8) / 8) * 8;
            MemOp::Fill(a, n, rng.next_u64())
        }
        _ => {
            // byte- or u64-wide copies between disjoint halves
            let celem = if elem == 4 { 8 } else { elem };
            let n = 1 + rng.below((3 * 4096 / celem).min(bytes / (2 * celem) - 1));
            let span = n * celem;
            let half = bytes / 2;
            let src = base + (rng.below(half - span) / celem) * celem;
            let dst = base + half + (rng.below(half - span) / celem) * celem;
            if rng.chance(0.5) {
                MemOp::Copy(dst, src, celem, n)
            } else {
                MemOp::Copy(src, dst, celem, n)
            }
        }
    }
}

/// ISSUE 5 acceptance: every bulk op is bit-identical to the scalar
/// loop it replaces — on flat `DirectMem` and on a *pressured* elastic
/// system where minor/remote faults land mid-span — for random
/// (addr, len, width) spans crossing page boundaries. Simulated time
/// is compared after every op; metrics, access counts, structural
/// invariants, and full-region readback at the end.
#[test]
fn prop_bulk_equals_scalar_on_direct_and_pressured_elastic() {
    Runner::new("bulk_scalar_equiv").with_cases(8).run(|rng: &mut Rng| {
        let frames = 40 + rng.below(24) as u32;
        let threshold = 8 + rng.below(64);
        let mode = if rng.chance(0.3) { Mode::Nswap } else { Mode::Elastic };
        let mut bulk_sys = sys_with(vec![frames, frames], mode, threshold);
        let mut scal_sys = sys_with(vec![frames, frames], mode, threshold);
        let mut bulk_dm = elastic_os::workloads::DirectMem::new();
        let mut scal_dm = elastic_os::workloads::DirectMem::new();
        // overcommit one node so faults land mid-bulk
        let pages = frames as u64 * 3 / 2;
        let bytes = pages * 4096;
        let base = bulk_sys.mmap(bytes, AreaKind::Heap, "bulk");
        assert_eq!(base, scal_sys.mmap(bytes, AreaKind::Heap, "bulk"));
        assert_eq!(base, bulk_dm.mmap(bytes, AreaKind::Heap, "bulk"));
        assert_eq!(base, scal_dm.mmap(bytes, AreaKind::Heap, "bulk"));

        let (mut oa, mut ob, mut oc, mut od) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for step in 0..100 {
            let op = gen_op(rng, base, bytes);
            apply_bulk(&mut bulk_sys, &op, &mut oa);
            apply_scalar(&mut scal_sys, &op, &mut ob);
            apply_bulk(&mut bulk_dm, &op, &mut oc);
            apply_scalar(&mut scal_dm, &op, &mut od);
            assert_eq!(oa, ob, "elastic read values diverged at step {step}");
            assert_eq!(oc, od, "direct read values diverged at step {step}");
            assert_eq!(oa, oc, "elastic vs direct read values diverged at step {step}");
            assert_eq!(
                bulk_sys.clock.now(),
                scal_sys.clock.now(),
                "simulated time diverged at step {step}"
            );
        }
        assert_eq!(bulk_sys.clock.accesses(), scal_sys.clock.accesses(), "access counts");
        assert_eq!(bulk_sys.metrics, scal_sys.metrics, "metrics diverged");
        bulk_sys.verify().expect("bulk system invariants");
        scal_sys.verify().expect("scalar system invariants");
        // full-region readback: all four memories agree word for word
        for p in 0..pages {
            let a = base + p * 4096;
            let v = bulk_sys.read_u64(a);
            assert_eq!(v, scal_sys.read_u64(a), "page {p}");
            assert_eq!(v, bulk_dm.read_u64(a), "page {p}");
            assert_eq!(v, scal_dm.read_u64(a), "page {p}");
        }
    });
}

/// State-sync replica convergence under random event sequences, and
/// the flush-before-jump ordering invariant.
#[test]
fn prop_sync_replica_convergence() {
    Runner::new("sync_convergence").with_cases(32).run(|rng: &mut Rng| {
        let mut leader = ProcessMeta::minimal(1, "p");
        let mut replica = leader.clone();
        let mut q = SyncQueue::new();
        let evs = gen::vec_of(rng, 1, 40, |rng| match rng.below(4) {
            0 => SyncEvent::Mmap(elastic_os::mem::addr::VmArea {
                start: rng.below(1 << 30) << 12,
                len: (1 + rng.below(64)) << 12,
                kind: AreaKind::Heap,
                name: "r".into(),
            }),
            1 => SyncEvent::Open { fd: rng.below(64) as u32, path: "/f".into(), flags: 0 },
            2 => SyncEvent::Close { fd: rng.below(64) as u32 },
            _ => SyncEvent::Renice { nice: (rng.below(40) as i64) - 20 },
        });
        for ev in evs {
            apply_event(&mut leader, &ev);
            q.enqueue(ev);
        }
        assert!(!q.is_flushed() || leader == replica);
        q.flush(|ev| apply_event(&mut replica, ev));
        assert!(q.is_flushed());
        assert_eq!(leader, replica, "replica must converge after flush");
    });
}

/// Jumping to every stretched node in random order keeps the system
/// consistent and execution lands where requested.
#[test]
fn prop_jump_sequence_consistent() {
    Runner::new("jump_sequence").with_cases(12).run(|rng: &mut Rng| {
        let nodes = 3usize;
        let mut sys = sys_with(vec![64; nodes], Mode::Elastic, u64::MAX);
        let a = sys.mmap(130 * 4096, AreaKind::Heap, "jmp");
        for p in 0..130u64 {
            sys.write_u64(a + p * 4096, p * 3);
        }
        // ensure all nodes are stretched before random jumping
        for n in 1..nodes as u8 {
            sys.stretch_to(NodeId(n));
        }
        for _ in 0..12 {
            let target = NodeId(rng.below(nodes as u64) as u8);
            if target != sys.running_on() {
                sys.jump_to(target);
                assert_eq!(sys.running_on(), target);
            }
            // interleave accesses
            for _ in 0..50 {
                let p = rng.below(130);
                assert_eq!(sys.read_u64(a + p * 4096), p * 3);
            }
            sys.verify().unwrap();
        }
    });
}
