//! Offline vendored facade for the `log` crate.
//!
//! Implements exactly the subset this repository uses: the five level
//! macros, the [`Log`] trait, and the global logger/level registry.
//! The API mirrors upstream `log` 0.4 so the real crate can be swapped
//! back in when a registry is available.

use std::cmp::Ordering as CmpOrdering;
use std::fmt;
use std::sync::RwLock;

/// Logging verbosity levels, most severe first.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn to_level_filter(&self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Level filter: like [`Level`] plus `Off`.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<CmpOrdering> {
        Some((*self as usize).cmp(&(*other as usize)))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<CmpOrdering> {
        Some((*self as usize).cmp(&(*other as usize)))
    }
}

/// Metadata about a log record.
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log event.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static LOGGER: RwLock<Option<&'static dyn Log>> = RwLock::new(None);
static MAX_LEVEL: RwLock<LevelFilter> = RwLock::new(LevelFilter::Off);

/// Error returned by [`set_logger`] when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let mut slot = LOGGER.write().unwrap_or_else(|e| e.into_inner());
    if slot.is_some() {
        return Err(SetLoggerError(()));
    }
    *slot = Some(logger);
    Ok(())
}

/// Set the global maximum level.
pub fn set_max_level(level: LevelFilter) {
    *MAX_LEVEL.write().unwrap_or_else(|e| e.into_inner()) = level;
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    *MAX_LEVEL.read().unwrap_or_else(|e| e.into_inner())
}

/// Dispatch one record to the installed logger (macro plumbing).
#[doc(hidden)]
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    let guard = LOGGER.read().unwrap_or_else(|e| e.into_inner());
    if let Some(logger) = *guard {
        let record = Record { metadata: Metadata { level, target }, args };
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => ({
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(format_args!($($arg)+), lvl, $target);
        }
    });
    ($lvl:expr, $($arg:tt)+) => ($crate::log!(target: module_path!(), $lvl, $($arg)+));
}

#[macro_export]
macro_rules! error {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Error, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Warn, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Info, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Debug, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Trace, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Info > LevelFilter::Warn);
        assert!(!(Level::Debug <= LevelFilter::Off));
        assert_eq!(Level::Warn, LevelFilter::Warn);
    }

    #[test]
    fn macros_compile_and_respect_level() {
        // No logger installed: must be a silent no-op at any level.
        set_max_level(LevelFilter::Trace);
        error!("e {}", 1);
        warn!("w");
        info!("i {x}", x = 3);
        debug!("d");
        trace!("t");
    }

    #[test]
    fn level_display_matches_upstream() {
        assert_eq!(Level::Warn.to_string(), "WARN");
        assert_eq!(format!("{:5}", Level::Info), "INFO ");
    }
}
