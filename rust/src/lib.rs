//! # ElasticOS — joint disaggregation of memory and computation
//!
//! A reproduction of *"Elasticizing Linux via Joint Disaggregation of
//! Memory and Computation"* (Ababneh et al., 2018) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the elastic-OS runtime: the four scaling
//!   primitives (*stretch*, *push*, *pull*, *jump*), the elastic page
//!   table, second-chance LRU + watermark-driven reclaim, the jumping
//!   policies, the network protocol (simulated-cost and real-TCP
//!   fabrics), the six evaluation workloads, and the harness that
//!   regenerates every table and figure of the paper. The engine is
//!   split into a shared node-kernel and per-process contexts
//!   ([`os::kernel`]), so one cluster runs N elasticized processes
//!   contending for the same frames ([`os::sched::ElasticCluster`]);
//!   [`os::system::ElasticSystem`] is the one-process facade.
//! * **L2 (python/compile/model.py)** — the adaptive jumping-policy and
//!   eviction-scoring compute graphs in JAX, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the decayed
//!   locality scoring and the vectorized second-chance aging, executed
//!   from the Rust decision path via PJRT (`runtime` module).
//!
//! Start with [`os::system::ElasticSystem`] (the engine) or the
//! `examples/` directory; DESIGN.md maps the paper onto the modules.

pub mod eval;
pub mod mem;
pub mod net;
pub mod os;
pub mod proc;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workloads;

pub use mem::{NodeId, PAGE_SIZE};
pub use os::sched::ElasticCluster;
pub use os::system::{ElasticSystem, Mode, SystemConfig};
pub use sim::CostModel;
