//! Process metadata — the kernel-space state the *stretch* checkpoint
//! carries (paper §4 "Stretching Implementation"): the process
//! descriptor, memory descriptor + vm areas, open-files table,
//! scheduling class, and signal handling table.  High-rate state
//! (registers, stack, pending signals) is deliberately NOT here — it
//! travels with *jump* checkpoints instead (§3.4).

use crate::mem::addr::VmArea;
use crate::util::{Dec, DecodeError, Enc};

/// Scheduling class (struct sched_class analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedClass {
    Normal,
    Batch,
    Idle,
    Fifo,
    RoundRobin,
}

impl SchedClass {
    fn tag(self) -> u8 {
        match self {
            SchedClass::Normal => 0,
            SchedClass::Batch => 1,
            SchedClass::Idle => 2,
            SchedClass::Fifo => 3,
            SchedClass::RoundRobin => 4,
        }
    }

    fn from_tag(tag: u8) -> Result<Self, DecodeError> {
        Ok(match tag {
            0 => SchedClass::Normal,
            1 => SchedClass::Batch,
            2 => SchedClass::Idle,
            3 => SchedClass::Fifo,
            4 => SchedClass::RoundRobin,
            t => return Err(DecodeError::BadTag { tag: t, what: "SchedClass" }),
        })
    }
}

/// An open file description (files_struct entry). The paper ships file
/// *names* and re-opens on the remote node (shared filesystem
/// assumption), so that is what we carry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpenFile {
    pub fd: u32,
    pub path: String,
    pub offset: u64,
    pub flags: u32,
}

impl OpenFile {
    pub fn encode(&self, e: &mut Enc) {
        e.u32(self.fd);
        e.str(&self.path);
        e.u64(self.offset);
        e.u32(self.flags);
    }

    pub fn decode(d: &mut Dec) -> Result<Self, DecodeError> {
        Ok(OpenFile { fd: d.u32()?, path: d.str(4096)?, offset: d.u64()?, flags: d.u32()? })
    }
}

/// A registered signal handler (sighand_struct entry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SigHandler {
    pub signo: u8,
    pub handler_addr: u64,
    pub flags: u64,
}

/// The stretch-checkpoint process metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessMeta {
    pub pid: u32,
    /// Command name (task_struct.comm).
    pub comm: String,
    /// Virtual memory areas (mm_struct + vm_area_structs).
    pub areas: Vec<VmArea>,
    /// Open file descriptors.
    pub files: Vec<OpenFile>,
    pub sched: SchedClass,
    pub nice: i64,
    pub handlers: Vec<SigHandler>,
    /// Credentials (uid/gid) — carried for completeness.
    pub uid: u32,
    pub gid: u32,
}

impl ProcessMeta {
    pub fn minimal(pid: u32, comm: &str) -> ProcessMeta {
        ProcessMeta {
            pid,
            comm: comm.to_string(),
            areas: Vec::new(),
            files: Vec::new(),
            sched: SchedClass::Normal,
            nice: 0,
            handlers: Vec::new(),
            uid: 1000,
            gid: 1000,
        }
    }

    pub fn encode(&self, e: &mut Enc) {
        e.u32(self.pid);
        e.str(&self.comm);
        e.u32(self.areas.len() as u32);
        for a in &self.areas {
            a.encode(e);
        }
        e.u32(self.files.len() as u32);
        for f in &self.files {
            f.encode(e);
        }
        e.u8(self.sched.tag());
        e.i64(self.nice);
        e.u32(self.handlers.len() as u32);
        for h in &self.handlers {
            e.u8(h.signo);
            e.u64(h.handler_addr);
            e.u64(h.flags);
        }
        e.u32(self.uid);
        e.u32(self.gid);
    }

    pub fn decode(d: &mut Dec) -> Result<Self, DecodeError> {
        let pid = d.u32()?;
        let comm = d.str(256)?;
        let n_areas = d.u32()? as usize;
        if n_areas > 4096 {
            return Err(DecodeError::TooLong { len: n_areas, limit: 4096 });
        }
        let mut areas = Vec::with_capacity(n_areas);
        for _ in 0..n_areas {
            areas.push(VmArea::decode(d)?);
        }
        let n_files = d.u32()? as usize;
        if n_files > 65536 {
            return Err(DecodeError::TooLong { len: n_files, limit: 65536 });
        }
        let mut files = Vec::with_capacity(n_files);
        for _ in 0..n_files {
            files.push(OpenFile::decode(d)?);
        }
        let sched = SchedClass::from_tag(d.u8()?)?;
        let nice = d.i64()?;
        let n_handlers = d.u32()? as usize;
        if n_handlers > 256 {
            return Err(DecodeError::TooLong { len: n_handlers, limit: 256 });
        }
        let mut handlers = Vec::with_capacity(n_handlers);
        for _ in 0..n_handlers {
            handlers.push(SigHandler { signo: d.u8()?, handler_addr: d.u64()?, flags: d.u64()? });
        }
        Ok(ProcessMeta { pid, comm, areas, files, sched, nice, handlers, uid: d.u32()?, gid: d.u32()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::AreaKind;

    fn sample() -> ProcessMeta {
        let mut m = ProcessMeta::minimal(1234, "a.out");
        m.areas.push(VmArea { start: 0x1000, len: 0x4000, kind: AreaKind::Heap, name: "heap".into() });
        m.areas.push(VmArea { start: 0x8000, len: 0x2000, kind: AreaKind::Stack, name: "stack".into() });
        m.files.push(OpenFile { fd: 0, path: "/dev/stdin".into(), offset: 0, flags: 0 });
        m.files.push(OpenFile { fd: 3, path: "/data/graph.bin".into(), offset: 4096, flags: 2 });
        m.handlers.push(SigHandler { signo: 17, handler_addr: 0xF00D, flags: 1 });
        m.sched = SchedClass::Batch;
        m.nice = 5;
        m
    }

    #[test]
    fn round_trip() {
        let m = sample();
        let mut e = Enc::new();
        m.encode(&mut e);
        let v = e.into_vec();
        let mut d = Dec::new(&v);
        assert_eq!(ProcessMeta::decode(&mut d).unwrap(), m);
        assert!(d.is_done());
    }

    #[test]
    fn minimal_is_small() {
        let m = ProcessMeta::minimal(1, "x");
        let mut e = Enc::new();
        m.encode(&mut e);
        // metadata alone is tiny; the stretch checkpoint's ~9 KB is
        // dominated by the data segment (see checkpoint.rs)
        assert!(e.len() < 256, "meta unexpectedly large: {}", e.len());
    }

    #[test]
    fn decode_rejects_absurd_counts() {
        let mut e = Enc::new();
        e.u32(1);
        e.str("x");
        e.u32(1_000_000); // areas count
        let v = e.into_vec();
        let mut d = Dec::new(&v);
        assert!(ProcessMeta::decode(&mut d).is_err());
    }
}
