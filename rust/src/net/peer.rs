//! Real-TCP peer runtime: two OS processes (or threads), each owning
//! one node's page frames, speaking the [`super::proto`] protocol over
//! sockets — stretch, push, pull, jump, done.  This is the proof that
//! nothing in the evaluation depends on the in-process simulation
//! shortcut: the same checkpoints and page messages cross a real wire,
//! and execution genuinely resumes on the peer after a jump
//! (examples/tcp_cluster.rs, rust/tests/tcp_transport.rs).
//!
//! The migrated computation is a resumable page scan ([`ScanTask`]):
//! its entire execution state is (position, accumulator) — it rides in
//! the jump checkpoint's register file exactly as the paper describes
//! ("registers and the top of the stack").

use super::proto::{read_msg, write_msg, Msg};
use crate::mem::addr::{NodeId, PAGE_SIZE};
use crate::proc::checkpoint::{JumpCheckpoint, RegisterFile};
use crate::proc::meta::ProcessMeta;
use crate::proc::StretchCheckpoint;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};

/// Fill pattern for page `p` (both sides can verify page integrity).
pub fn page_fill(p: u32) -> u8 {
    (p as u64).wrapping_mul(0x9E3779B9) as u8
}

/// Expected scan digest over `n_pages` (ground truth).
pub fn expected_digest(n_pages: u32) -> u64 {
    let mut acc = 0u64;
    for p in 0..n_pages {
        acc = acc.wrapping_add(page_digest(p));
    }
    acc
}

fn page_digest(p: u32) -> u64 {
    // sum of the page's bytes = PAGE_SIZE * fill
    PAGE_SIZE as u64 * page_fill(p) as u64
}

/// The migrating computation: scan all pages, summing their bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanTask {
    pub n_pages: u32,
    pub pos: u32,
    pub acc: u64,
}

impl ScanTask {
    /// Pack into a register file (the jump checkpoint's thread context).
    pub fn to_regs(self) -> RegisterFile {
        let mut r = RegisterFile::default();
        r.gpr[0] = self.n_pages as u64;
        r.gpr[1] = self.pos as u64;
        r.gpr[2] = self.acc;
        r.rip = 0x401000 + self.pos as u64; // cosmetic
        r
    }

    pub fn from_regs(r: &RegisterFile) -> ScanTask {
        ScanTask { n_pages: r.gpr[0] as u32, pos: r.gpr[1] as u32, acc: r.gpr[2] }
    }
}

/// Per-peer statistics.
#[derive(Debug, Default, Clone)]
pub struct PeerStats {
    pub pulls: u64,
    pub pulls_served: u64,
    pub pushes_received: u64,
    pub jumps_sent: u64,
    pub jumps_received: u64,
    pub bytes_sent: u64,
    /// Pages that rode along with a faulting pull in a batched reply
    /// (one round-trip and one wire latency for the whole window).
    pub prefetched: u64,
    /// Far tier: pages shipped to a memory server in `DemoteBatch`es
    /// (on the server report: pages deposited with it).
    pub demoted: u64,
    /// Far tier: pages brought back via `PromoteReq`/`PromoteData`
    /// (on the server report: pages it served back).
    pub promoted: u64,
    /// Membership: pages moved by the drain protocol (sent on the
    /// departing side, absorbed on the surviving side).
    pub drained: u64,
}

/// Outcome of a peer session.
#[derive(Debug, Clone)]
pub struct PeerReport {
    pub node: NodeId,
    pub digest: u64,
    pub stats: PeerStats,
}

struct Conn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl Conn {
    fn new(stream: TcpStream) -> Result<Conn> {
        stream.set_nodelay(true)?;
        let r = BufReader::new(stream.try_clone()?);
        let w = BufWriter::new(stream);
        Ok(Conn { r, w })
    }

    fn send(&mut self, msg: &Msg, stats: &mut PeerStats) -> Result<()> {
        stats.bytes_sent += msg.wire_size();
        write_msg(&mut self.w, msg).context("send")
    }

    fn recv(&mut self) -> Result<Msg> {
        read_msg(&mut self.r).context("recv")
    }
}

/// One peer's state: its page store + connection to the other peer.
pub struct Peer {
    pub node: NodeId,
    conn: Conn,
    store: HashMap<u32, Vec<u8>>,
    stats: PeerStats,
    /// Jump threshold: consecutive remote pulls before jumping.
    threshold: u32,
    /// Pull-prefetch window: with n > 0 a remote fault asks for the
    /// faulting page plus up to n spatially-following pages in one
    /// `PullBatchReq` (0 = per-page pulls).
    prefetch: u32,
    shell: Option<ProcessMeta>,
    /// Connection to a far-memory server (frames only, no execution),
    /// if one is attached.
    far: Option<Conn>,
    /// Pages this peer has demoted to the far server (the far half of
    /// its page table: a miss here is a far fault, not a peer pull).
    far_pages: std::collections::HashSet<u32>,
    /// The other peer announced `Leave` and drained out: no more
    /// requests may be sent to it, and no replies will come.
    peer_departed: bool,
}

/// Bounded reconnect policy for [`Peer::connect_retry`] and
/// [`Peer::reconnect`]: a worker process that was killed and is being
/// restarted (redeployed, rescheduled) needs its peers to keep dialing
/// for a bounded window instead of failing on the first refused
/// connection — and to give up with an error rather than spin forever.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Maximum connect attempts (>= 1; 1 = the plain single try).
    pub attempts: u32,
    /// Sleep before the second attempt; doubles each retry.
    pub initial_backoff: std::time::Duration,
    /// Backoff ceiling for the exponential doubling.
    pub max_backoff: std::time::Duration,
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: std::time::Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 20,
            initial_backoff: std::time::Duration::from_millis(10),
            max_backoff: std::time::Duration::from_millis(500),
            connect_timeout: std::time::Duration::from_secs(2),
        }
    }
}

/// Dial `addr` under `policy`: per-attempt connect timeout, capped
/// exponential backoff between attempts, hard attempt bound.
fn retry_connect(addr: &str, policy: &RetryPolicy) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let mut backoff = policy.initial_backoff;
    let attempts = policy.attempts.max(1);
    let mut last_err: Option<anyhow::Error> = None;
    for attempt in 1..=attempts {
        let addrs = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .collect::<Vec<_>>();
        for sa in &addrs {
            match TcpStream::connect_timeout(sa, policy.connect_timeout) {
                Ok(stream) => {
                    if attempt > 1 {
                        log::info!("connected to {addr} on attempt {attempt}/{attempts}");
                    }
                    return Ok(stream);
                }
                Err(e) => last_err = Some(e.into()),
            }
        }
        if attempt < attempts {
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(policy.max_backoff);
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow::anyhow!("{addr} resolved to no addresses"))
        .context(format!("connecting to {addr}: {attempts} attempt(s) exhausted")))
}

impl Peer {
    /// Leader side: connect to the worker's listener.
    pub fn connect(node: NodeId, addr: &str, threshold: u32) -> Result<Peer> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Ok(Peer::new(node, stream, threshold))
    }

    /// [`Peer::connect`] with a bounded retry/backoff window — the dial
    /// path for a peer that may still be starting (or restarting).
    pub fn connect_retry(
        node: NodeId,
        addr: &str,
        threshold: u32,
        policy: &RetryPolicy,
    ) -> Result<Peer> {
        let stream = retry_connect(addr, policy)?;
        Ok(Peer::new(node, stream, threshold))
    }

    /// Re-dial `addr` after the remote end died mid-session, replacing
    /// this peer's connection. Page store, stats, and far-tier state
    /// survive; the protocol restarts from the handshake (the caller
    /// re-runs [`Peer::leader_handshake`]).
    pub fn reconnect(&mut self, addr: &str, policy: &RetryPolicy) -> Result<()> {
        let stream = retry_connect(addr, policy)?;
        self.conn = Conn::new(stream)?;
        Ok(())
    }

    /// Worker side: accept one connection.
    pub fn accept(node: NodeId, listener: &TcpListener, threshold: u32) -> Result<Peer> {
        let (stream, _) = listener.accept().context("accept")?;
        Ok(Peer::new(node, stream, threshold))
    }

    fn new(node: NodeId, stream: TcpStream, threshold: u32) -> Peer {
        Peer {
            node,
            conn: Conn::new(stream).expect("conn setup"),
            store: HashMap::new(),
            stats: PeerStats::default(),
            threshold,
            prefetch: 0,
            shell: None,
            far: None,
            far_pages: std::collections::HashSet::new(),
            peer_departed: false,
        }
    }

    /// Attach a far-memory server (leader side): pages demoted there
    /// come back on demand as `PromoteReq`/`PromoteData` round-trips.
    pub fn attach_far(&mut self, addr: &str) -> Result<()> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to far server {addr}"))?;
        self.far = Some(Conn::new(stream)?);
        Ok(())
    }

    /// Release the far server: send `Bye` so its serve loop exits.
    pub fn detach_far(&mut self) -> Result<()> {
        if let Some(mut far) = self.far.take() {
            far.send(&Msg::Bye, &mut self.stats)?;
        }
        Ok(())
    }

    /// Demote locally-resident pages in `[lo, hi)` to the far server
    /// in `MAX_BATCH`-bounded `DemoteBatch`es (memory pressure: the
    /// frames are freed here, the bytes live on the server). Returns
    /// how many pages moved.
    pub fn demote_range(&mut self, lo: u32, hi: u32) -> Result<u32> {
        let far = self.far.as_mut().context("no far server attached")?;
        let idxs: Vec<u32> = (lo..hi).filter(|p| self.store.contains_key(p)).collect();
        let mut moved = 0u32;
        for chunk in idxs.chunks(super::proto::MAX_BATCH) {
            let pages: Vec<(u32, Vec<u8>)> = chunk
                .iter()
                .map(|p| (*p, self.store.remove(p).expect("filtered to resident pages")))
                .collect();
            moved += pages.len() as u32;
            for (p, _) in &pages {
                self.far_pages.insert(*p);
            }
            far.send(&Msg::DemoteBatch { pages }, &mut self.stats)?;
        }
        self.stats.demoted += moved as u64;
        Ok(moved)
    }

    /// Far fault: promote the faulting page plus up to the prefetch
    /// window of spatially-following far pages in one round-trip.
    fn promote_window(&mut self, p: u32) -> Result<()> {
        let window = self.prefetch.min(super::proto::MAX_BATCH as u32 - 1);
        let idxs: Vec<u32> =
            (p..p + 1 + window).filter(|i| *i == p || self.far_pages.contains(i)).collect();
        let far = self.far.as_mut().context("far fault with no far server attached")?;
        far.send(&Msg::PromoteReq { idxs }, &mut self.stats)?;
        match far.recv()? {
            Msg::PromoteData { pages } => {
                anyhow::ensure!(
                    pages.first().map(|(i, _)| *i) == Some(p),
                    "promote reply missing the faulting page {p}"
                );
                for (i, data) in pages {
                    self.far_pages.remove(&i);
                    self.stats.promoted += 1;
                    self.store.insert(i, data);
                }
                Ok(())
            }
            m => bail!("expected PromoteData, got {m:?}"),
        }
    }

    /// Enable pull batching: each remote fault requests up to `n`
    /// spatially-following pages alongside the faulting one. Clamped
    /// so the window (faulting page included) never exceeds the
    /// codec's [`MAX_BATCH`](super::proto::MAX_BATCH) — an oversized
    /// request would be rejected by the serving peer's decoder.
    pub fn set_prefetch(&mut self, n: u32) {
        self.prefetch = n.min(super::proto::MAX_BATCH as u32 - 1);
    }

    /// Seed this peer's store with pages [lo, hi).
    pub fn seed_pages(&mut self, lo: u32, hi: u32) {
        for p in lo..hi {
            self.store.insert(p, vec![page_fill(p); PAGE_SIZE]);
        }
    }

    pub fn stats(&self) -> &PeerStats {
        &self.stats
    }

    /// Leader: announce + stretch the process to the worker.
    pub fn leader_handshake(&mut self, meta: &ProcessMeta) -> Result<()> {
        self.conn.send(
            &Msg::Hello { node: self.node, ram_frames: 1024 },
            &mut self.stats,
        )?;
        match self.conn.recv()? {
            Msg::Hello { node, .. } => log::info!("worker announced as {node}"),
            m => bail!("expected Hello, got {m:?}"),
        }
        let ckpt = StretchCheckpoint { meta: meta.clone(), data_segment: vec![0; 8192] };
        self.conn.send(&Msg::Stretch { ckpt: ckpt.encode() }, &mut self.stats)?;
        match self.conn.recv()? {
            Msg::StretchAck => Ok(()),
            m => bail!("expected StretchAck, got {m:?}"),
        }
    }

    /// Worker: answer the handshake, creating the suspended shell.
    pub fn worker_handshake(&mut self) -> Result<()> {
        match self.conn.recv()? {
            Msg::Hello { node, .. } => log::info!("leader announced as {node}"),
            m => bail!("expected Hello, got {m:?}"),
        }
        self.conn.send(&Msg::Hello { node: self.node, ram_frames: 1024 }, &mut self.stats)?;
        match self.conn.recv()? {
            Msg::Stretch { ckpt } => {
                let ckpt = StretchCheckpoint::decode(&ckpt)?;
                self.shell = Some(ckpt.meta);
                self.conn.send(&Msg::StretchAck, &mut self.stats)?;
                Ok(())
            }
            m => bail!("expected Stretch, got {m:?}"),
        }
    }

    /// Run as the active executor from `task` until the scan finishes
    /// here or jumps away; then serve passively. Returns the final
    /// digest (whichever side computed it).
    pub fn run_active(&mut self, task: ScanTask) -> Result<u64> {
        match self.execute(task)? {
            Some(digest) => {
                if self.peer_departed {
                    // Nobody left to notify: the peer drained and Left.
                    return Ok(digest);
                }
                // we finished: tell the peer and wind down
                self.conn.send(&Msg::Done { digest, stats: vec![] }, &mut self.stats)?;
                match self.conn.recv()? {
                    Msg::Bye => {}
                    m => bail!("expected Bye, got {m:?}"),
                }
                Ok(digest)
            }
            None => self.run_passive(),
        }
    }

    /// Serve pulls/pushes/jumps until someone reports Done.
    pub fn run_passive(&mut self) -> Result<u64> {
        loop {
            match self.conn.recv()? {
                Msg::PullReq { idx } => {
                    let data = self
                        .store
                        .remove(&idx)
                        .with_context(|| format!("pull of page {idx} we do not own"))?;
                    self.stats.pulls_served += 1;
                    self.conn.send(&Msg::PullData { idx, data }, &mut self.stats)?;
                }
                Msg::Push { idx, data } => {
                    self.stats.pushes_received += 1;
                    self.store.insert(idx, data);
                }
                Msg::PullBatchReq { idxs } => {
                    // Serve in request order; pages this peer does not
                    // own are skipped (the requester's prefetch window
                    // may overrun our holdings).
                    let mut pages = Vec::with_capacity(idxs.len());
                    for idx in idxs {
                        if let Some(data) = self.store.remove(&idx) {
                            self.stats.pulls_served += 1;
                            pages.push((idx, data));
                        }
                    }
                    self.conn.send(&Msg::PullBatchData { pages }, &mut self.stats)?;
                }
                Msg::PushBatch { pages } => {
                    self.stats.pushes_received += pages.len() as u64;
                    for (idx, data) in pages {
                        self.store.insert(idx, data);
                    }
                }
                Msg::Jump { ckpt } => {
                    self.stats.jumps_received += 1;
                    let ckpt = JumpCheckpoint::decode(&ckpt)?;
                    let task = ScanTask::from_regs(&ckpt.regs);
                    log::info!("{}: resumed at page {} via jump", self.node, task.pos);
                    if let Some(digest) = self.execute(task)? {
                        self.conn.send(&Msg::Done { digest, stats: vec![] }, &mut self.stats)?;
                        match self.conn.recv()? {
                            Msg::Bye => {}
                            m => bail!("expected Bye, got {m:?}"),
                        }
                        return Ok(digest);
                    }
                    // jumped away again; keep serving
                }
                Msg::Done { digest, .. } => {
                    self.conn.send(&Msg::Bye, &mut self.stats)?;
                    return Ok(digest);
                }
                Msg::Join { announce } => {
                    // A late joiner introducing itself (paper §4: every
                    // participant records the announce). The two-peer
                    // demo has no third socket to adopt, so this is
                    // bookkeeping only.
                    log::info!("{}: recorded join announce ({} bytes)", self.node, announce.len());
                }
                Msg::Drain { node, remaining } => {
                    // Drain header: the departing peer's pages follow as
                    // ordinary PushBatches; `remaining` lets us log
                    // progress without trusting message counts.
                    log::info!("{}: drain from {node}, {remaining} page(s) to go", self.node);
                }
                Msg::Leave { node } => {
                    // The *active* peer may not Leave while we hold no
                    // execution context — it must Done or Jump first.
                    bail!("{node} announced Leave while this peer was passive with no work");
                }
                m => bail!("unexpected message while passive: {m:?}"),
            }
        }
    }

    /// Serve like [`Self::run_passive`] for `serve_limit` messages,
    /// then retire: announce `Drain`, push every resident page back in
    /// `MAX_BATCH`-bounded batches, announce `Leave`, and depart. The
    /// mid-run inverse of the join handshake — the paper's protocol
    /// run backwards. Returns pages drained out.
    pub fn run_passive_leave(&mut self, serve_limit: u32) -> Result<u32> {
        for _ in 0..serve_limit {
            match self.conn.recv()? {
                Msg::PullReq { idx } => {
                    let data = self
                        .store
                        .remove(&idx)
                        .with_context(|| format!("pull of page {idx} we do not own"))?;
                    self.stats.pulls_served += 1;
                    self.conn.send(&Msg::PullData { idx, data }, &mut self.stats)?;
                }
                Msg::PullBatchReq { idxs } => {
                    let mut pages = Vec::with_capacity(idxs.len());
                    for idx in idxs {
                        if let Some(data) = self.store.remove(&idx) {
                            self.stats.pulls_served += 1;
                            pages.push((idx, data));
                        }
                    }
                    self.conn.send(&Msg::PullBatchData { pages }, &mut self.stats)?;
                }
                Msg::Push { idx, data } => {
                    self.stats.pushes_received += 1;
                    self.store.insert(idx, data);
                }
                Msg::PushBatch { pages } => {
                    self.stats.pushes_received += pages.len() as u64;
                    for (idx, data) in pages {
                        self.store.insert(idx, data);
                    }
                }
                Msg::Done { digest: _, .. } => {
                    // The scan finished before our scripted departure:
                    // nothing left to drain, just wind down normally.
                    self.conn.send(&Msg::Bye, &mut self.stats)?;
                    return Ok(0);
                }
                m => bail!("unexpected message while passive: {m:?}"),
            }
        }
        // Retire: drain every resident page, then Leave. Sorted order
        // keeps the wire trace reproducible run to run.
        let mut idxs: Vec<u32> = self.store.keys().copied().collect();
        idxs.sort_unstable();
        let total = idxs.len() as u32;
        let mut sent = 0u32;
        for chunk in idxs.chunks(super::proto::MAX_BATCH) {
            let pages: Vec<(u32, Vec<u8>)> = chunk
                .iter()
                .map(|p| (*p, self.store.remove(p).expect("key from this store")))
                .collect();
            sent += pages.len() as u32;
            self.conn
                .send(&Msg::Drain { node: self.node, remaining: total - sent }, &mut self.stats)?;
            self.conn.send(&Msg::PushBatch { pages }, &mut self.stats)?;
        }
        self.stats.drained += sent as u64;
        self.conn.send(&Msg::Leave { node: self.node }, &mut self.stats)?;
        Ok(sent)
    }

    /// Receive while absorbing an in-flight departure: `Drain` headers
    /// and drain `PushBatch`es are folded into the local store, and a
    /// `Leave` marks the peer gone and returns `None` (the request we
    /// were awaiting a reply to will never be answered — but the drain
    /// that preceded the Leave delivered the pages it concerned).
    fn recv_or_departure(&mut self) -> Result<Option<Msg>> {
        loop {
            match self.conn.recv()? {
                Msg::Drain { node, remaining } => {
                    log::info!("{}: drain from {node}, {remaining} page(s) to go", self.node);
                }
                Msg::PushBatch { pages } => {
                    self.stats.drained += pages.len() as u64;
                    for (idx, data) in pages {
                        self.store.insert(idx, data);
                    }
                }
                Msg::Leave { node } => {
                    log::info!("{}: {node} departed mid-run; continuing solo", self.node);
                    self.peer_departed = true;
                    return Ok(None);
                }
                m => return Ok(Some(m)),
            }
        }
    }

    /// Execute the scan from `task`. Returns Some(digest) if finished
    /// locally, or None if execution jumped to the peer.
    fn execute(&mut self, mut task: ScanTask) -> Result<Option<u64>> {
        let mut consecutive_remote = 0u32;
        while task.pos < task.n_pages {
            let p = task.pos;
            if let Some(data) = self.store.get(&p) {
                // locally resident from the start of this streak
                consecutive_remote = 0;
                task.acc = task.acc.wrapping_add(data.iter().map(|&b| b as u64).sum::<u64>());
                task.pos += 1;
                continue;
            }
            if self.far_pages.contains(&p) {
                // Far fault: the page lives on the memory server, not
                // the peer — promote it (plus its window) back. Far
                // faults never feed the jump counter: jumping to the
                // peer would not dodge the far server's latency.
                self.promote_window(p)?;
                continue; // p is local now; the loop re-reads it
            }
            if self.peer_departed {
                bail!("page {p} unresident after the peer drained out and departed");
            }
            // remote page: the paper's counter counts *pulls*, so a
            // page we just pulled must not reset the streak
            consecutive_remote += 1;
            if consecutive_remote > self.threshold {
                // jump to the data instead of pulling it all here
                let ckpt = JumpCheckpoint::new(task.to_regs());
                self.stats.jumps_sent += 1;
                self.conn.send(&Msg::Jump { ckpt: ckpt.encode() }, &mut self.stats)?;
                return Ok(None);
            }
            if self.prefetch > 0 {
                // Batched pull: the faulting page plus its spatial
                // window in one round-trip. Pages already local are
                // filtered out of the request.
                let idxs: Vec<u32> = (p..task.n_pages.min(p + 1 + self.prefetch))
                    .filter(|i| *i == p || !self.store.contains_key(i))
                    .collect();
                self.conn.send(&Msg::PullBatchReq { idxs }, &mut self.stats)?;
                match self.recv_or_departure()? {
                    Some(Msg::PullBatchData { pages }) => {
                        anyhow::ensure!(
                            pages.first().map(|(i, _)| *i) == Some(p),
                            "batched pull reply missing the faulting page {p}"
                        );
                        self.stats.pulls += 1;
                        self.stats.prefetched += pages.len() as u64 - 1;
                        for (i, data) in pages {
                            self.store.insert(i, data);
                        }
                        // p is local now; the loop re-reads it (and the
                        // window behind it) from the store
                    }
                    // Departed mid-request: the drain that preceded the
                    // Leave delivered every page it still held — the
                    // loop re-reads p from the local store.
                    None => {}
                    Some(m) => bail!("expected PullBatchData, got {m:?}"),
                }
                continue;
            }
            self.conn.send(&Msg::PullReq { idx: p }, &mut self.stats)?;
            match self.recv_or_departure()? {
                Some(Msg::PullData { idx, data }) => {
                    anyhow::ensure!(idx == p, "pull reply for wrong page");
                    self.stats.pulls += 1;
                    task.acc =
                        task.acc.wrapping_add(data.iter().map(|&b| b as u64).sum::<u64>());
                    task.pos += 1;
                    self.store.insert(p, data);
                }
                None => {} // departed; p arrived in the drain — re-read it
                Some(m) => bail!("expected PullData, got {m:?}"),
            }
        }
        Ok(Some(task.acc))
    }
}

/// A far-memory endpoint: frames only, no execution. Accepts
/// `DemoteBatch` deposits and serves `PromoteReq` withdrawals over the
/// same codec the peers speak, until the client says `Bye`.
pub struct MemoryServer {
    pub node: NodeId,
    conn: Conn,
    store: HashMap<u32, Vec<u8>>,
    stats: PeerStats,
}

impl MemoryServer {
    /// Accept one client connection.
    pub fn accept(node: NodeId, listener: &TcpListener) -> Result<MemoryServer> {
        let (stream, _) = listener.accept().context("accept")?;
        Ok(MemoryServer {
            node,
            conn: Conn::new(stream)?,
            store: HashMap::new(),
            stats: PeerStats::default(),
        })
    }

    /// Serve demotes and promotes until the client sends `Bye`.
    pub fn serve(&mut self) -> Result<()> {
        loop {
            match self.conn.recv()? {
                Msg::DemoteBatch { pages } => {
                    self.stats.demoted += pages.len() as u64;
                    for (idx, data) in pages {
                        self.store.insert(idx, data);
                    }
                }
                Msg::PromoteReq { idxs } => {
                    // Serve in request order; pages we do not hold are
                    // skipped (the client's window may overrun).
                    let mut pages = Vec::with_capacity(idxs.len());
                    for idx in idxs {
                        if let Some(data) = self.store.remove(&idx) {
                            self.stats.promoted += 1;
                            pages.push((idx, data));
                        }
                    }
                    self.conn.send(&Msg::PromoteData { pages }, &mut self.stats)?;
                }
                Msg::Bye => return Ok(()),
                m => bail!("unexpected message at memory server: {m:?}"),
            }
        }
    }

    pub fn stats(&self) -> &PeerStats {
        &self.stats
    }

    /// Pages currently deposited with this server.
    pub fn resident(&self) -> usize {
        self.store.len()
    }
}

/// Convenience: run a full two-peer session over localhost, worker in
/// a thread. Returns (leader report, worker report).
pub fn run_local_pair(n_pages: u32, threshold: u32) -> Result<(PeerReport, PeerReport)> {
    run_local_pair_opts(n_pages, threshold, 0)
}

/// [`run_local_pair`] with a pull-prefetch window: both sides request
/// batched pulls of up to `prefetch` extra pages per remote fault.
pub fn run_local_pair_opts(
    n_pages: u32,
    threshold: u32,
    prefetch: u32,
) -> Result<(PeerReport, PeerReport)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let split = n_pages / 2;

    let worker = std::thread::spawn(move || -> Result<PeerReport> {
        let mut peer = Peer::accept(NodeId(1), &listener, threshold)?;
        peer.set_prefetch(prefetch);
        peer.seed_pages(split, n_pages);
        peer.worker_handshake()?;
        let digest = peer.run_passive()?;
        Ok(PeerReport { node: NodeId(1), digest, stats: peer.stats().clone() })
    });

    let mut leader = Peer::connect(NodeId(0), &addr.to_string(), threshold)?;
    leader.set_prefetch(prefetch);
    leader.seed_pages(0, split);
    let meta = ProcessMeta::minimal(42, "scan");
    leader.leader_handshake(&meta)?;
    let task = ScanTask { n_pages, pos: 0, acc: 0 };
    let digest = leader.run_active(task)?;
    let leader_report =
        PeerReport { node: NodeId(0), digest, stats: leader.stats().clone() };

    let worker_report = worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    Ok((leader_report, worker_report))
}

/// [`run_local_pair_opts`] plus one far-memory server: the leader
/// demotes the upper half of its seeded pages to the server up front
/// (memory pressure), then promotes them back on demand while the scan
/// runs — `DemoteBatch`/`PromoteReq`/`PromoteData` over a real wire.
/// Returns (leader, worker, server) reports; the server's digest field
/// is 0 (it never executes).
pub fn run_local_far(
    n_pages: u32,
    threshold: u32,
    prefetch: u32,
) -> Result<(PeerReport, PeerReport, PeerReport)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let far_listener = TcpListener::bind("127.0.0.1:0")?;
    let far_addr = far_listener.local_addr()?;
    let split = n_pages / 2;

    let worker = std::thread::spawn(move || -> Result<PeerReport> {
        let mut peer = Peer::accept(NodeId(1), &listener, threshold)?;
        peer.set_prefetch(prefetch);
        peer.seed_pages(split, n_pages);
        peer.worker_handshake()?;
        let digest = peer.run_passive()?;
        Ok(PeerReport { node: NodeId(1), digest, stats: peer.stats().clone() })
    });
    let server = std::thread::spawn(move || -> Result<PeerReport> {
        let mut srv = MemoryServer::accept(NodeId(2), &far_listener)?;
        srv.serve()?;
        anyhow::ensure!(
            srv.resident() == 0,
            "{} pages stranded on the memory server",
            srv.resident()
        );
        Ok(PeerReport { node: NodeId(2), digest: 0, stats: srv.stats().clone() })
    });

    let mut leader = Peer::connect(NodeId(0), &addr.to_string(), threshold)?;
    leader.set_prefetch(prefetch);
    leader.seed_pages(0, split);
    leader.attach_far(&far_addr.to_string())?;
    // Memory pressure: the upper half of the leader's own pages go to
    // the far tier; the sequential scan will far-fault them all back.
    leader.demote_range(split / 2, split)?;
    let meta = ProcessMeta::minimal(42, "scan");
    leader.leader_handshake(&meta)?;
    let task = ScanTask { n_pages, pos: 0, acc: 0 };
    let digest = leader.run_active(task)?;
    leader.detach_far()?;
    let leader_report = PeerReport { node: NodeId(0), digest, stats: leader.stats().clone() };

    let worker_report = worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    let server_report = server.join().map_err(|_| anyhow::anyhow!("server panicked"))??;
    Ok((leader_report, worker_report, server_report))
}

/// Mid-run leave demo over localhost: the worker serves the leader's
/// first `serve_limit` requests, then retires cleanly — `Drain`
/// header, its whole residual page store in `PushBatch`es, `Leave` —
/// and departs. The leader absorbs the drain (possibly while a pull
/// of its own is in flight), marks the peer departed, and finishes the
/// scan solo on the drained pages. The graceful inverse of
/// [`run_local_restart`]'s crash-stop. Returns (leader report, worker
/// report, pages drained).
pub fn run_local_leave(
    n_pages: u32,
    threshold: u32,
    serve_limit: u32,
) -> Result<(PeerReport, PeerReport, u32)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let split = n_pages / 2;

    let worker = std::thread::spawn(move || -> Result<(PeerReport, u32)> {
        let mut peer = Peer::accept(NodeId(1), &listener, threshold)?;
        peer.seed_pages(split, n_pages);
        peer.worker_handshake()?;
        let drained = peer.run_passive_leave(serve_limit)?;
        Ok((PeerReport { node: NodeId(1), digest: 0, stats: peer.stats().clone() }, drained))
    });

    let mut leader = Peer::connect(NodeId(0), &addr.to_string(), threshold)?;
    leader.seed_pages(0, split);
    let meta = ProcessMeta::minimal(42, "scan");
    leader.leader_handshake(&meta)?;
    let task = ScanTask { n_pages, pos: 0, acc: 0 };
    let digest = leader.run_active(task)?;
    let leader_report = PeerReport { node: NodeId(0), digest, stats: leader.stats().clone() };

    let (worker_report, drained) =
        worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    Ok((leader_report, worker_report, drained))
}

/// Kill-and-restart demo over localhost: the worker's first
/// incarnation accepts the leader's connection and dies on the spot
/// (crash-stop mid-handshake, socket dropped with no goodbye); a
/// restarted incarnation then accepts again and serves a full session.
/// The leader survives by detecting the dead connection, re-dialing
/// under the bounded [`RetryPolicy`], and re-running the handshake.
/// Returns (leader, worker, reconnects).
pub fn run_local_restart(n_pages: u32, threshold: u32) -> Result<(PeerReport, PeerReport, u32)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let split = n_pages / 2;

    let worker = std::thread::spawn(move || -> Result<PeerReport> {
        // First incarnation: killed before answering the handshake.
        let (dead, _) = listener.accept().context("accept (first incarnation)")?;
        drop(dead);
        // Restarted incarnation: same listener, fresh session state.
        let mut peer = Peer::accept(NodeId(1), &listener, threshold)?;
        peer.seed_pages(split, n_pages);
        peer.worker_handshake()?;
        let digest = peer.run_passive()?;
        Ok(PeerReport { node: NodeId(1), digest, stats: peer.stats().clone() })
    });

    let mut leader = Peer::connect(NodeId(0), &addr.to_string(), threshold)?;
    leader.seed_pages(0, split);
    let meta = ProcessMeta::minimal(42, "scan");
    let mut reconnects = 0u32;
    if let Err(e) = leader.leader_handshake(&meta) {
        log::info!("worker died mid-handshake ({e:#}); reconnecting");
        leader.reconnect(&addr.to_string(), &RetryPolicy::default())?;
        reconnects += 1;
        leader.leader_handshake(&meta).context("handshake after reconnect")?;
    }
    let task = ScanTask { n_pages, pos: 0, acc: 0 };
    let digest = leader.run_active(task)?;
    let leader_report = PeerReport { node: NodeId(0), digest, stats: leader.stats().clone() };

    let worker_report = worker.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    Ok((leader_report, worker_report, reconnects))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_task_register_round_trip() {
        let t = ScanTask { n_pages: 100, pos: 37, acc: 0xABCDEF };
        assert_eq!(ScanTask::from_regs(&t.to_regs()), t);
    }

    #[test]
    fn expected_digest_is_stable() {
        assert_eq!(expected_digest(4), (0..4).map(page_digest).sum::<u64>());
    }

    #[test]
    fn connect_retry_gives_up_after_bounded_attempts() {
        // Bind-then-drop yields a port with (almost certainly) no
        // listener, so every dial is refused quickly.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let policy = RetryPolicy {
            attempts: 3,
            initial_backoff: std::time::Duration::from_millis(1),
            max_backoff: std::time::Duration::from_millis(4),
            connect_timeout: std::time::Duration::from_millis(250),
        };
        let t0 = std::time::Instant::now();
        let r = Peer::connect_retry(NodeId(0), &addr.to_string(), 8, &policy);
        assert!(r.is_err(), "no listener: the bounded dial must fail");
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "3 bounded attempts must not spin for seconds"
        );
    }

    #[test]
    fn worker_leaves_mid_run_and_leader_finishes_solo() {
        // Threshold = n_pages: the leader never jumps, so the worker's
        // scripted departure is the only membership event. It serves 4
        // pulls, then drains its remaining pages and Leaves; the leader
        // finishes the scan on the drained pages with the exact digest.
        let (leader, worker, drained) = run_local_leave(64, 64, 4).unwrap();
        assert_eq!(leader.digest, expected_digest(64), "leader digest after solo finish");
        assert!(drained > 0, "the worker must have pages left to drain");
        assert_eq!(worker.stats.pulls_served, 4, "scripted serve window before the leave");
        assert_eq!(worker.stats.drained as u32, drained, "drain accounting matches");
        assert_eq!(leader.stats.drained as u32, drained, "every drained page was absorbed");
    }

    #[test]
    fn leader_survives_killed_and_restarted_worker() {
        let (leader, worker, reconnects) = run_local_restart(64, 8).unwrap();
        assert_eq!(reconnects, 1, "the first incarnation's death must force one reconnect");
        let expect = expected_digest(64);
        assert_eq!(leader.digest, expect, "leader digest after reconnect");
        assert_eq!(worker.digest, expect, "restarted worker digest");
    }
}
