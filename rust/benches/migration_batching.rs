//! Fault-path win from batched migration (ISSUE 4): the same
//! overcommitted sequential scan with per-page pulls vs batched
//! pull-prefetch + PushBatch reclaim. Reports simulated time, fault
//! counts, and the amortized wire latency, then wall-clocks both
//! configurations (batching also shrinks the emulator's slow-path
//! work: fewer fault handler entries per page moved).
//! `cargo bench --bench migration_batching`.

mod bench_util;

use bench_util::bench;
use elastic_os::os::system::{ElasticSystem, Mode, SystemConfig};
use elastic_os::util::stats::fmt_ns;
use elastic_os::workloads::{by_name, Scale};

const FRAMES: u32 = 512;
const FOOTPRINT: u64 = (FRAMES as u64 * 4096 * 13) / 10; // 1.3x home node

fn run_with(push_batch: u32, prefetch: u32) -> (u64, u64, u64, u64) {
    let cfg = SystemConfig {
        node_frames: vec![FRAMES, FRAMES],
        mode: Mode::Elastic,
        push_batch,
        prefetch,
        ..SystemConfig::default()
    };
    let mut sys = ElasticSystem::new(cfg, 512);
    let mut w = by_name("linear", Scale::Bytes(FOOTPRINT)).unwrap();
    let r = sys.run_workload(w.as_mut());
    (r.sim_ns, r.metrics.remote_faults, r.metrics.prefetch_pulled, sys.batch_saved_ns())
}

fn main() {
    println!("== migration_batching ==");
    let configs = [
        ("per-page (batch=1, prefetch=0)", 1u32, 0u32),
        ("push batching only (batch=8)", 8, 0),
        ("pull prefetch only (prefetch=8)", 1, 8),
        ("both (batch=8, prefetch=8)", 8, 8),
    ];
    for (label, batch, prefetch) in configs {
        let (sim, faults, prefetched, saved) = run_with(batch, prefetch);
        println!(
            "{label:<36} sim={:>10} remote_faults={faults:<6} prefetched={prefetched:<6} wire_saved={}",
            fmt_ns(sim as f64),
            fmt_ns(saved as f64),
        );
    }
    for (label, batch, prefetch) in [("wall: per-page", 1u32, 0u32), ("wall: batched", 8, 8)] {
        bench(label, 1, 5, || {
            std::hint::black_box(run_with(batch, prefetch));
        });
    }
}
