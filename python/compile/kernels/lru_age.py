"""L1 Pallas kernel: vectorized second-chance LRU aging / eviction scoring.

ElasticOS's *push* primitive piggybacks on the kernel swap daemon's LRU
page scanner (paper sec. 3.2): pages mapped by elasticized processes are
scanned, aged, and the coldest are pushed to the remote replica.  This
kernel is the scanner's inner loop, batched over a block of page
metadata: it applies the classic second-chance update (referenced pages
get their age reset and their reference bit cleared; unreferenced pages
age by one) and emits an eviction priority per page (higher = evict
sooner).  Dirty pages are slightly deprioritized (they cost a writeback)
and pinned pages are excluded with a -inf-like penalty.

Block shape is fixed at AOT time (default 2048 pages = 3 * 8 KiB of VMEM
per operand block — trivially VMEM-resident on TPU).  interpret=True for
CPU-PJRT (see locality.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B = 2048  # pages per scan block

# Priority penalties (must match rust/src/runtime/evict_model.rs and
# kernels/ref.py).
DIRTY_PENALTY = 0.25
PIN_PENALTY = 1.0e9


def _lru_age_kernel(age_ref, refd_ref, dirty_ref, pinned_ref, new_age_ref, prio_ref):
    """Second-chance update + eviction priority for one block of pages."""
    age = age_ref[...]
    refd = refd_ref[...]
    dirty = dirty_ref[...]
    pinned = pinned_ref[...]
    # Referenced pages get a second chance: age resets to zero.
    new_age = jnp.where(refd > 0.5, jnp.zeros_like(age), age + 1.0)
    prio = new_age - DIRTY_PENALTY * dirty - PIN_PENALTY * pinned
    new_age_ref[...] = new_age
    prio_ref[...] = prio


@functools.partial(jax.jit, static_argnames=("b",))
def lru_age(age, refd, dirty, pinned, *, b: int = DEFAULT_B):
    """Batched second-chance aging.

    Args:
      age:    f32[b] current age (scans since last reference).
      refd:   f32[b] reference bit (0/1), analog of PG_ACCESSED.
      dirty:  f32[b] dirty bit (0/1).
      pinned: f32[b] pin bit (0/1) — never evict.

    Returns:
      (new_age f32[b], priority f32[b]); priority is higher for colder
      pages, hugely negative for pinned pages.
    """
    return pl.pallas_call(
        _lru_age_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ),
        interpret=True,
    )(age, refd, dirty, pinned)
