//! `elasticos` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   run       run one workload under eos/nswap and print the report
//!   eval      regenerate a paper table/figure (or `all`)
//!   cluster   real-TCP two-process demo (leader/worker)
//!   info      environment + artifact status
//!
//! (clap is unavailable in the offline build; `cli` is a hand-rolled
//! parser — see DESIGN.md §3.)

mod cli;

use cli::Args;
use elastic_os::eval::{experiments, EvalConfig};
use elastic_os::mem::NodeId;
use elastic_os::os::system::{ElasticSystem, Mode};
use elastic_os::os::EwmaPolicy;
use elastic_os::workloads::{by_name, Scale};

fn main() {
    elastic_os::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let code = match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("eval") => cmd_eval(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
elasticos — ElasticOS: joint disaggregation of memory and computation

USAGE:
  elasticos run --workload <name> [--mode eos|nswap] [--threshold N]
                [--frames F] [--footprint BYTES] [--policy threshold|ewma|burst|model]
  elasticos eval <table1|table2|table3|fig8|fig9|fig10|fig11|fig12|fig13|fig14|fig15|
                  ablation-policy|ablation-balance|multinode|all> [--fast]
  elasticos cluster [--pages N] [--threshold N]
  elasticos info

Workloads: dfs linear dijkstra block_sort heap_sort count_sort table_scan";

fn cmd_run(args: &Args) -> i32 {
    let workload = args.flag("workload").unwrap_or_else(|| "linear".into());
    let mode = match args.flag("mode").as_deref() {
        Some("nswap") => Mode::Nswap,
        _ => Mode::Elastic,
    };
    let threshold: u64 = args.flag_parse("threshold").unwrap_or(512);
    let frames: u32 = args.flag_parse("frames").unwrap_or(2048);
    let footprint: u64 =
        args.flag_parse("footprint").unwrap_or(frames as u64 * 4096 * 13 / 10);

    let Some(mut w) = by_name(&workload, Scale::Bytes(footprint)) else {
        eprintln!("unknown workload '{workload}'");
        return 2;
    };
    let mut sc = elastic_os::os::system::SystemConfig {
        node_frames: vec![frames, frames],
        mode,
        ..Default::default()
    };
    if let Some(n) = args.flag_parse::<usize>("nodes") {
        sc.node_frames = vec![frames; n];
    }
    let mut sys = match args.flag("policy").as_deref() {
        Some("ewma") => ElasticSystem::with_policy(sc, Box::new(EwmaPolicy::default_tuned())),
        Some("burst") => ElasticSystem::with_policy(
            sc,
            Box::new(elastic_os::os::BurstPolicy::default_tuned()),
        ),
        Some("model") => {
            let engine = match elastic_os::runtime::Engine::cpu() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("PJRT unavailable: {e}");
                    return 1;
                }
            };
            let path = elastic_os::runtime::artifacts_dir().join("policy.hlo.txt");
            let model = match engine.load(&path) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("cannot load {} (run `make artifacts`): {e}", path.display());
                    return 1;
                }
            };
            let policy = elastic_os::runtime::ModelJumpPolicy::new(
                model,
                elastic_os::runtime::policy_model::ModelPolicyParams::default(),
            );
            ElasticSystem::with_policy(sc, Box::new(policy))
        }
        _ => ElasticSystem::new(sc, threshold),
    };
    let report = sys.run_workload(w.as_mut());
    println!("{}", report.summary_line());
    println!(
        "  minor={} stretches={} syncs={} wall={}",
        report.metrics.minor_faults,
        report.metrics.stretches,
        report.metrics.sync_events,
        elastic_os::util::stats::fmt_ns(report.wall_ns as f64),
    );
    0
}

fn cmd_eval(args: &Args) -> i32 {
    let name = args.positional.get(1).cloned().unwrap_or_else(|| "all".into());
    let mut cfg = if args.has("fast") { EvalConfig::fast() } else { EvalConfig::default() };
    if let Some(f) = args.flag_parse::<u32>("frames") {
        cfg.node_frames = f;
        cfg.footprint = f as u64 * 4096 * 13 / 10;
    }
    if let Some(r) = args.flag_parse::<u32>("repeats") {
        cfg.repeats = r;
    }
    if experiments::run_named(&cfg, &name) {
        0
    } else {
        eprintln!("unknown experiment '{name}'");
        2
    }
}

fn cmd_cluster(args: &Args) -> i32 {
    let pages: u32 = args.flag_parse("pages").unwrap_or(2048);
    let threshold: u32 = args.flag_parse("threshold").unwrap_or(32);
    match elastic_os::net::peer::run_local_pair(pages, threshold) {
        Ok((leader, worker)) => {
            let expect = elastic_os::net::peer::expected_digest(pages);
            println!("leader: node={} digest={:#x}", leader.node, leader.digest);
            println!(
                "  pulls={} served={} jumps_sent={} bytes={}",
                leader.stats.pulls,
                leader.stats.pulls_served,
                leader.stats.jumps_sent,
                leader.stats.bytes_sent
            );
            println!("worker: node={} digest={:#x}", worker.node, worker.digest);
            println!(
                "  pulls={} served={} jumps_recv={} bytes={}",
                worker.stats.pulls,
                worker.stats.pulls_served,
                worker.stats.jumps_received,
                worker.stats.bytes_sent
            );
            if leader.digest == expect && worker.digest == expect {
                println!("digest OK ({expect:#x})");
                0
            } else {
                eprintln!("DIGEST MISMATCH: expected {expect:#x}");
                1
            }
        }
        Err(e) => {
            eprintln!("cluster failed: {e:#}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("elastic_os {}", env!("CARGO_PKG_VERSION"));
    let dir = elastic_os::runtime::artifacts_dir();
    for f in ["policy.hlo.txt", "evict.hlo.txt"] {
        let p = dir.join(f);
        println!(
            "artifact {}: {}",
            p.display(),
            if p.exists() { "present" } else { "MISSING (make artifacts)" }
        );
    }
    match elastic_os::runtime::Engine::cpu() {
        Ok(_) => println!("PJRT CPU client: ok"),
        Err(e) => println!("PJRT CPU client: FAILED ({e})"),
    }
    let _ = NodeId(0);
    0
}
