//! End-to-end workload benches: wall time of complete emulated runs at
//! bench scale (the simulated-time results are the eval harness's job;
//! this tracks the emulator's own speed so perf regressions show up).
//! `cargo bench --bench end_to_end`.

mod bench_util;

use bench_util::bench;
use elastic_os::os::system::{ElasticSystem, Mode, SystemConfig};
use elastic_os::workloads::{by_name, Scale, ALL};

fn main() {
    println!("== end_to_end (emulator wall time per full run, 2x512-frame nodes) ==");
    let footprint = 512 * 4096 * 13 / 10;
    for wl in ALL {
        for (mode, threshold) in [(Mode::Nswap, 512u64), (Mode::Elastic, 512)] {
            let label = format!("{wl} [{}]", mode.as_str());
            bench(&label, 1, 5, || {
                let mut w = by_name(wl, Scale::Bytes(footprint)).unwrap();
                let cfg = SystemConfig {
                    node_frames: vec![512, 512],
                    mode,
                    ..SystemConfig::default()
                };
                let mut sys = ElasticSystem::new(cfg, threshold);
                let r = sys.run_workload(w.as_mut());
                std::hint::black_box(r.digest);
            });
        }
    }
}
