//! Node-kernel LRU lists over *all* elasticized processes.
//!
//! The single-process engine used [`super::lru::LruLists`], an intrusive
//! list over one process's dense page-index space. With N concurrent
//! processes per cluster a node's reclaim scanner must order the pages
//! of *every* process resident in its pool — Linux's per-zone LRU does
//! not care which `mm_struct` a page belongs to, and neither does the
//! paper's page balancer (§3.2). [`ClusterLru`] is that structure: one
//! cold→hot list per node whose elements are [`PageKey`]s, i.e.
//! `(process slot, page index)` pairs.
//!
//! Representation: an arena of links plus a `HashMap` from key to arena
//! slot. Every operation is O(1) amortized. The map is only ever used
//! for point lookups — iteration always walks the intrusive list — so
//! ordering (and therefore the whole simulation) stays deterministic.

use super::addr::{NodeId, MAX_NODES};
use super::page_table::PageIdx;
// lint: allow(determinism) reason=point lookups only; iteration always walks the intrusive list
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

/// Identity of a page in the cluster: which process (by process-table
/// slot) and which page of its address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageKey {
    /// Process-table slot (stable for the life of the cluster).
    pub proc: u32,
    /// Dense page index within that process's elastic page table.
    pub idx: PageIdx,
}

#[derive(Debug, Clone, Copy)]
struct Link {
    key: PageKey,
    prev: u32,
    next: u32,
    /// Which node's list this link is on.
    on: u32,
}

/// Per-node LRU lists keyed by (process, page).
#[derive(Debug)]
pub struct ClusterLru {
    links: Vec<Link>,
    free: Vec<u32>,
    // lint: allow(determinism) reason=point lookups only; iteration walks the list
    slot_of: HashMap<PageKey, u32>,
    head: [u32; MAX_NODES],
    tail: [u32; MAX_NODES],
    len: [u32; MAX_NODES],
}

impl ClusterLru {
    pub fn new() -> ClusterLru {
        ClusterLru {
            links: Vec::new(),
            free: Vec::new(),
            // lint: allow(determinism) reason=point lookups only; never iterated
            slot_of: HashMap::new(),
            head: [NIL; MAX_NODES],
            tail: [NIL; MAX_NODES],
            len: [0; MAX_NODES],
        }
    }

    #[inline]
    pub fn len(&self, node: NodeId) -> u32 {
        self.len[node.0 as usize]
    }

    pub fn is_empty(&self, node: NodeId) -> bool {
        self.len(node) == 0
    }

    /// Which node's list holds this page, if any.
    pub fn list_of(&self, key: PageKey) -> Option<NodeId> {
        self.slot_of.get(&key).map(|&s| NodeId(self.links[s as usize].on as u8))
    }

    /// Take a link arena slot (reusing freed slots first).
    fn alloc_slot(&mut self, link: Link) -> u32 {
        match self.free.pop() {
            Some(s) => {
                self.links[s as usize] = link;
                s
            }
            None => {
                self.links.push(link);
                (self.links.len() - 1) as u32
            }
        }
    }

    /// Insert at the hot (MRU) end.
    pub fn push_hot(&mut self, node: NodeId, key: PageKey) {
        debug_assert!(!self.slot_of.contains_key(&key), "page {key:?} already on a list");
        let n = node.0 as usize;
        let old_tail = self.tail[n];
        let slot = self.alloc_slot(Link { key, prev: old_tail, next: NIL, on: node.0 as u32 });
        if old_tail == NIL {
            self.head[n] = slot;
        } else {
            self.links[old_tail as usize].next = slot;
        }
        self.tail[n] = slot;
        self.len[n] += 1;
        self.slot_of.insert(key, slot);
    }

    /// Insert at the cold (LRU) end — how speculatively pulled
    /// (prefetched) pages enter a node's list, so a wrong guess is the
    /// first thing the reclaim scanner evicts.
    pub fn push_cold(&mut self, node: NodeId, key: PageKey) {
        debug_assert!(!self.slot_of.contains_key(&key), "page {key:?} already on a list");
        let n = node.0 as usize;
        let old_head = self.head[n];
        let slot = self.alloc_slot(Link { key, prev: NIL, next: old_head, on: node.0 as u32 });
        if old_head == NIL {
            self.tail[n] = slot;
        } else {
            self.links[old_head as usize].prev = slot;
        }
        self.head[n] = slot;
        self.len[n] += 1;
        self.slot_of.insert(key, slot);
    }

    /// Coldest page (LRU end), if any.
    #[inline]
    pub fn coldest(&self, node: NodeId) -> Option<PageKey> {
        let h = self.head[node.0 as usize];
        if h == NIL {
            None
        } else {
            Some(self.links[h as usize].key)
        }
    }

    /// Remove a specific page from whatever list it is on.
    pub fn remove(&mut self, key: PageKey) {
        let slot = self.slot_of.remove(&key).unwrap_or_else(|| {
            panic!("removing page {key:?} that is on no list");
        });
        let link = self.links[slot as usize];
        let n = link.on as usize;
        if link.prev == NIL {
            self.head[n] = link.next;
        } else {
            self.links[link.prev as usize].next = link.next;
        }
        if link.next == NIL {
            self.tail[n] = link.prev;
        } else {
            self.links[link.next as usize].prev = link.prev;
        }
        self.len[n] -= 1;
        self.free.push(slot);
    }

    /// Second-chance rotation: move the coldest page to the hot end.
    pub fn rotate(&mut self, node: NodeId) {
        if let Some(key) = self.coldest(node) {
            self.remove(key);
            self.push_hot(node, key);
        }
    }

    /// Touch: move a page to the hot end of whatever list it is on.
    pub fn touch(&mut self, key: PageKey) {
        if let Some(node) = self.list_of(key) {
            self.remove(key);
            self.push_hot(node, key);
        }
    }

    /// Unlink *every* page on `node`'s list and return the keys in
    /// cold → hot order, leaving other nodes' lists untouched. This
    /// pins the drop-set semantics of node retirement: the drain
    /// protocol in `os::membership` unlinks exactly these (process,
    /// page) entries — one at a time, via `move_page`/`remove`, so
    /// each page can be migrated or stashed as it leaves — and the
    /// tests below assert the set-level behavior the two paths share.
    pub fn drain_node(&mut self, node: NodeId) -> Vec<PageKey> {
        let keys: Vec<PageKey> = self.iter(node).collect();
        for key in &keys {
            self.remove(*key);
        }
        keys
    }

    /// Peek the up-to-`n` coldest entries on `node`'s list in cold →
    /// hot order, leaving the list untouched — the victim window
    /// batched reclaim (kswapd / direct reclaim / balance / drain)
    /// filters and ships as one `PushBatch`. A pure read: unlike the
    /// second-chance scan it never rotates or clears referenced bits,
    /// so peeking costs nothing when the batch is abandoned.
    pub fn harvest_cold(&self, node: NodeId, n: u32) -> Vec<PageKey> {
        self.iter(node).take(n as usize).collect()
    }

    /// Iterate cold → hot over one node's list.
    pub fn iter(&self, node: NodeId) -> ClusterLruIter<'_> {
        ClusterLruIter { lru: self, cur: self.head[node.0 as usize] }
    }

    /// Check internal consistency for one node's list (tests).
    pub fn verify(&self, node: NodeId) -> Result<(), String> {
        let n = node.0 as usize;
        let mut count = 0u32;
        let mut cur = self.head[n];
        let mut prev = NIL;
        while cur != NIL {
            let link = self.links[cur as usize];
            if link.on != n as u32 {
                return Err(format!("page {:?} linked into list {n} but tagged {}", link.key, link.on));
            }
            if link.prev != prev {
                return Err(format!("back-pointer broken at {:?}", link.key));
            }
            if self.slot_of.get(&link.key) != Some(&cur) {
                return Err(format!("slot map out of sync for {:?}", link.key));
            }
            prev = cur;
            cur = link.next;
            count += 1;
            if count > self.links.len() as u32 {
                return Err("cycle detected".into());
            }
        }
        if self.tail[n] != prev {
            return Err("tail pointer broken".into());
        }
        if count != self.len[n] {
            return Err(format!("len cache {} != actual {count}", self.len[n]));
        }
        Ok(())
    }
}

impl Default for ClusterLru {
    fn default() -> Self {
        ClusterLru::new()
    }
}

/// Cold-to-hot iterator.
pub struct ClusterLruIter<'a> {
    lru: &'a ClusterLru,
    cur: u32,
}

impl Iterator for ClusterLruIter<'_> {
    type Item = PageKey;

    fn next(&mut self) -> Option<PageKey> {
        if self.cur == NIL {
            return None;
        }
        let link = self.lru.links[self.cur as usize];
        self.cur = link.next;
        Some(link.key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(i: u8) -> NodeId {
        NodeId(i)
    }

    fn k(proc_slot: u32, idx: PageIdx) -> PageKey {
        PageKey { proc: proc_slot, idx }
    }

    #[test]
    fn push_order_is_cold_to_hot() {
        let mut l = ClusterLru::new();
        l.push_hot(n(0), k(0, 1));
        l.push_hot(n(0), k(1, 1));
        l.push_hot(n(0), k(0, 2));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(0, 1), k(1, 1), k(0, 2)]);
        assert_eq!(l.coldest(n(0)), Some(k(0, 1)));
        l.verify(n(0)).unwrap();
    }

    #[test]
    fn same_idx_different_procs_are_distinct() {
        let mut l = ClusterLru::new();
        l.push_hot(n(0), k(0, 7));
        l.push_hot(n(0), k(1, 7));
        l.remove(k(0, 7));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(1, 7)]);
        assert_eq!(l.list_of(k(0, 7)), None);
        assert_eq!(l.list_of(k(1, 7)), Some(n(0)));
        l.verify(n(0)).unwrap();
    }

    #[test]
    fn remove_middle_and_slot_reuse() {
        let mut l = ClusterLru::new();
        for i in 1..=3 {
            l.push_hot(n(0), k(0, i));
        }
        l.remove(k(0, 2));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(0, 1), k(0, 3)]);
        // freed arena slot gets reused
        l.push_hot(n(1), k(2, 9));
        assert_eq!(l.links.len(), 3);
        l.verify(n(0)).unwrap();
        l.verify(n(1)).unwrap();
    }

    #[test]
    fn rotate_gives_second_chance() {
        let mut l = ClusterLru::new();
        for i in 1..=3 {
            l.push_hot(n(0), k(0, i));
        }
        l.rotate(n(0));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(0, 2), k(0, 3), k(0, 1)]);
        l.verify(n(0)).unwrap();
    }

    #[test]
    fn touch_moves_to_hot_end() {
        let mut l = ClusterLru::new();
        for i in 1..=3 {
            l.push_hot(n(0), k(1, i));
        }
        l.touch(k(1, 1));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(1, 2), k(1, 3), k(1, 1)]);
        l.touch(k(9, 9)); // not on any list: no-op
    }

    #[test]
    fn page_moves_between_node_lists() {
        let mut l = ClusterLru::new();
        l.push_hot(n(0), k(0, 5));
        l.remove(k(0, 5));
        l.push_hot(n(1), k(0, 5));
        assert!(l.is_empty(n(0)));
        assert_eq!(l.coldest(n(1)), Some(k(0, 5)));
    }

    #[test]
    fn empty_list_behaviour() {
        let mut l = ClusterLru::new();
        assert_eq!(l.coldest(n(0)), None);
        l.rotate(n(0)); // no-op, no panic
        assert!(l.iter(n(0)).next().is_none());
    }

    #[test]
    fn drain_node_removes_exactly_that_nodes_entries() {
        // Satellite regression: node departure must drop exactly the
        // departed node's (pid, page) entries, nothing else.
        let mut l = ClusterLru::new();
        l.push_hot(n(0), k(0, 1));
        l.push_hot(n(1), k(0, 2));
        l.push_hot(n(1), k(1, 2));
        l.push_hot(n(2), k(1, 3));
        let drained = l.drain_node(n(1));
        assert_eq!(drained, vec![k(0, 2), k(1, 2)], "cold -> hot order");
        assert!(l.is_empty(n(1)));
        assert_eq!(l.list_of(k(0, 2)), None);
        assert_eq!(l.list_of(k(1, 2)), None);
        // survivors untouched, on their original lists
        assert_eq!(l.list_of(k(0, 1)), Some(n(0)));
        assert_eq!(l.list_of(k(1, 3)), Some(n(2)));
        for node in 0..3 {
            l.verify(n(node)).unwrap();
        }
        // draining an empty list is a no-op
        assert!(l.drain_node(n(1)).is_empty());
        // drained keys can re-enter on a surviving node (migration)
        l.push_hot(n(0), k(0, 2));
        assert_eq!(l.list_of(k(0, 2)), Some(n(0)));
        l.verify(n(0)).unwrap();
    }

    #[test]
    fn push_cold_lands_at_the_lru_end() {
        let mut l = ClusterLru::new();
        l.push_hot(n(0), k(0, 1));
        l.push_hot(n(0), k(0, 2));
        l.push_cold(n(0), k(0, 3)); // a prefetched page: coldest
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(0, 3), k(0, 1), k(0, 2)]);
        assert_eq!(l.coldest(n(0)), Some(k(0, 3)));
        // a touch promotes it like any resident page
        l.touch(k(0, 3));
        assert_eq!(l.iter(n(0)).collect::<Vec<_>>(), vec![k(0, 1), k(0, 2), k(0, 3)]);
        l.verify(n(0)).unwrap();
        // cold insert into an empty list sets both ends
        l.push_cold(n(1), k(1, 9));
        assert_eq!(l.coldest(n(1)), Some(k(1, 9)));
        l.verify(n(1)).unwrap();
    }

    #[test]
    fn harvest_cold_peeks_without_mutating() {
        let mut l = ClusterLru::new();
        for i in 1..=5 {
            l.push_hot(n(0), k(0, i));
        }
        assert_eq!(l.harvest_cold(n(0), 3), vec![k(0, 1), k(0, 2), k(0, 3)]);
        // asking for more than exists truncates; the list is unchanged
        assert_eq!(l.harvest_cold(n(0), 99).len(), 5);
        assert_eq!(l.len(n(0)), 5);
        assert_eq!(l.coldest(n(0)), Some(k(0, 1)));
        assert!(l.harvest_cold(n(1), 4).is_empty());
        l.verify(n(0)).unwrap();
    }

    #[test]
    fn stress_random_ops_stay_consistent() {
        use crate::util::Rng;
        let mut rng = Rng::new(0xC10C);
        let mut l = ClusterLru::new();
        // membership model: (proc in 0..4, idx in 0..32) -> node
        let mut member: Vec<Option<u8>> = vec![None; 4 * 32];
        for _ in 0..8000 {
            let proc_slot = rng.below(4) as u32;
            let idx = rng.below(32) as PageIdx;
            let key = k(proc_slot, idx);
            let m = (proc_slot * 32 + idx) as usize;
            match member[m] {
                None => {
                    let node = rng.below(4) as u8;
                    l.push_hot(n(node), key);
                    member[m] = Some(node);
                }
                Some(_) => {
                    if rng.chance(0.4) {
                        l.remove(key);
                        member[m] = None;
                    } else {
                        l.touch(key);
                    }
                }
            }
        }
        for node in 0..4u8 {
            l.verify(n(node)).unwrap();
            let expect = member.iter().filter(|m| **m == Some(node)).count() as u32;
            assert_eq!(l.len(n(node)), expect);
        }
    }
}
