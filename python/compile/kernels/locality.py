"""L1 Pallas kernel: decayed remote-fault locality scoring.

This is the compute hot-spot of the ElasticOS *jumping policy* (paper
sec. 3.4 "Jumping Policy Algorithm" + sec. 6 future work on adaptive
policies): given a sliding window of remote-page-fault counts, bucketed
by time and attributed to the node whose RAM holds the faulting page,
compute an exponentially-decayed "locality mass" per node.  The EOS
manager jumps the process towards the node with the largest mass when the
margin over the currently-running node exceeds a hysteresis.

Shapes are deliberately tiny and fixed at AOT time: the window is
``(W, N)`` with ``W`` time buckets and ``N`` cluster-node slots (unused
slots are zero).  On a real TPU this is a single-VMEM-block kernel
(W*N*4 bytes = 4 KiB for the default 64x16 window, far below VMEM);
``interpret=True`` is mandatory for CPU-PJRT execution (real lowering
emits a Mosaic custom-call the CPU plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT shapes; the rust runtime compiles against exactly these.
DEFAULT_W = 64  # time buckets in the fault window
DEFAULT_N = 16  # maximum cluster nodes


def _locality_kernel(window_ref, decay_ref, out_ref, *, w: int, n: int):
    """Pallas kernel body: out[n] = sum_t decay^(W-1-t) * window[t, n].

    Bucket ``W-1`` is the newest (weight 1.0); bucket 0 the oldest
    (weight decay^(W-1)).  Weights are built with broadcasted_iota so the
    whole body is vector ops on the VPU — no MXU needed.
    """
    window = window_ref[...]  # (W, N) f32
    decay = decay_ref[0]  # scalar f32 in (0, 1]
    # exponent for bucket t is (W-1-t)
    t = jax.lax.broadcasted_iota(jnp.float32, (w, n), 0)
    exponent = jnp.float32(w - 1) - t
    # decay^e computed as exp(e * log(decay)); clamp to avoid log(0).
    log_d = jnp.log(jnp.maximum(decay, jnp.float32(1e-30)))
    weights = jnp.exp(exponent * log_d)
    out_ref[...] = jnp.sum(window * weights, axis=0)


@functools.partial(jax.jit, static_argnames=("w", "n"))
def locality_scores(window, decay, *, w: int = DEFAULT_W, n: int = DEFAULT_N):
    """Decayed per-node locality mass.

    Args:
      window: f32[w, n] remote-fault counts (row W-1 newest).
      decay:  f32[1] per-bucket decay factor in (0, 1].

    Returns:
      f32[n] decayed mass per node.
    """
    return pl.pallas_call(
        functools.partial(_locality_kernel, w=w, n=n),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls
    )(window, decay)
