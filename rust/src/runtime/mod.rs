//! PJRT runtime: loads the AOT-compiled L2/L1 artifacts (HLO text
//! emitted by `python/compile/aot.py`) and executes them from the Rust
//! decision paths.  Python never runs here — the HLO text is compiled
//! once by the in-process XLA CPU client at startup.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod evict_model;
pub mod policy_model;

use anyhow::{Context, Result};
use std::path::Path;

pub use evict_model::ModelEvictor;
pub use policy_model::ModelJumpPolicy;

/// Shared PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!("PJRT platform: {} ({} devices)", client.platform_name(), client.device_count());
        Ok(Engine { client })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Model> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("compiling HLO")?;
        Ok(Model { exe, name: path.display().to_string() })
    }
}

/// One compiled executable (jax function lowered with
/// `return_tuple=True`, so outputs always come back as a tuple).
pub struct Model {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Model {
    /// Execute with f32 inputs of the given shapes; returns each tuple
    /// element flattened to a f32 vec.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 && dims[0] as usize == data.len() {
                    Ok(lit)
                } else {
                    lit.reshape(dims).map_err(anyhow::Error::from)
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Resolve the artifacts directory: $ELASTICOS_ARTIFACTS or
/// ./artifacts relative to the workspace root.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("ELASTICOS_ARTIFACTS") {
        return d.into();
    }
    for base in [".", "..", "../.."] {
        let p = std::path::Path::new(base).join("artifacts");
        if p.join("policy.hlo.txt").exists() {
            return p;
        }
    }
    "artifacts".into()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are also
    /// covered by rust/tests/runtime_pjrt.rs which skips cleanly.
    fn artifacts_present() -> bool {
        artifacts_dir().join("policy.hlo.txt").exists()
    }

    #[test]
    fn load_and_run_policy_artifact() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = Engine::cpu().unwrap();
        let model = eng.load(artifacts_dir().join("policy.hlo.txt")).unwrap();
        let window = vec![0f32; 64 * 16];
        let mut onehot = vec![0f32; 16];
        onehot[0] = 1.0;
        let params = vec![0.9f32, 1.0, 4.0, 0.0];
        let out = model
            .run_f32(&[(&window, &[64, 16]), (&onehot, &[16]), (&params, &[4])])
            .unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].len(), 16);
        assert_eq!(out[2][0], 0.0, "zero window must not jump");
    }
}
